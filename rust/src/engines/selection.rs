//! Range-selection compute engine (paper §IV, Figure 4 / Algorithm 1).
//!
//! Scans a column of 32-bit integers and emits the indexes of values
//! inside `[lo, hi]`. The hardware engine alternates between an *ingress*
//! pipeline (DMA-read 512-bit lines → 16 parallel compare/update units →
//! per-lane on-chip result buffers) and an *egress* pipeline (assemble
//! 512-bit result lines → DMA-write), switching every `BUFFER_SIZE` input
//! lines. Because the 16 lanes buffer matches independently, egress lines
//! are padded with a dummy element wherever a lane produced fewer matches
//! than the fullest lane — exactly the trick the paper notes is also
//! needed for SIMD CPUs.
//!
//! Timing model: ingress and egress time-share the engine's single shim
//! port, so consumption rate degrades with selectivity (Fig. 6); each
//! ingress/egress switch costs [`SWITCH_OVERHEAD_CYCLES`] (pipeline
//! fill/drain — calibrated so one engine sustains the paper's 11 GB/s at
//! 0% selectivity against the 12.8 GB/s port).

use super::pipeline::{cycles_to_secs, LINE_BYTES, PARALLELISM};
use super::{Engine, Phase};
use crate::hbm::memory::{HbmMemory, MemBytes};
use crate::hbm::shim::ShimBuffer;
use crate::hbm::HbmConfig;

/// Input lines per ingress/egress switch (paper: 1024 → 64 KiB of
/// per-lane index buffers).
pub const BUFFER_SIZE: usize = 1024;
/// Padding value for unfilled egress lanes.
pub const DUMMY: u32 = u32::MAX;
/// Pipeline fill/drain cost per ingress/egress switch, in cycles
/// (calibrated to the paper's 11 GB/s single-engine rate at 0% selectivity).
pub const SWITCH_OVERHEAD_CYCLES: f64 = 88.0;

/// Job description for one selection engine.
#[derive(Debug, Clone)]
pub struct SelectionJob {
    /// Column slice this engine scans.
    pub input: ShimBuffer,
    /// Number of 32-bit items in `input`.
    pub items: u64,
    /// Global index of the first item (partitioned inputs).
    pub index_base: u32,
    /// Inclusive range predicate.
    pub lo: u32,
    pub hi: u32,
    /// Output buffer for padded index lines.
    pub output: ShimBuffer,
}

/// Functional + timing model of one selection engine.
pub struct SelectionEngine {
    cfg: HbmConfig,
    job: SelectionJob,
    /// Timing phase produced by the functional pass, awaiting emission.
    phase: Option<Phase>,
    prepared: bool,
    /// Filled after the scan: total matches (excluding padding).
    pub matches: u64,
    /// Bytes of (padded) output produced.
    pub out_bytes: u64,
}

impl SelectionEngine {
    pub fn new(cfg: HbmConfig, job: SelectionJob) -> Self {
        Self { cfg, job, phase: None, prepared: false, matches: 0, out_bytes: 0 }
    }

    /// Run the scan functionally: read the column through the shim, apply
    /// the predicate per lane, write padded result lines. Returns
    /// (matches, padded output lines).
    fn scan(&mut self, mem: &mut dyn MemBytes) -> (u64, u64) {
        let items = self.job.items as usize;
        let data = self.job.input.read_u32s(mem, 0, items);
        let chunk_items = BUFFER_SIZE * PARALLELISM;
        let mut total_matches = 0u64;
        let mut out_lines = 0u64;
        let mut out_words: Vec<u32> = Vec::new();

        for (ci, chunk) in data.chunks(chunk_items).enumerate() {
            // Per-lane match buffers (lane = item index mod PARALLELISM,
            // the spatial partitioning of the 16 update units).
            let mut lanes: Vec<Vec<u32>> = vec![Vec::new(); PARALLELISM];
            for (i, &v) in chunk.iter().enumerate() {
                if v >= self.job.lo && v <= self.job.hi {
                    let global = self.job.index_base
                        + (ci * chunk_items + i) as u32;
                    lanes[i % PARALLELISM].push(global);
                }
            }
            let max_lane = lanes.iter().map(|l| l.len()).max().unwrap_or(0);
            total_matches += lanes.iter().map(|l| l.len() as u64).sum::<u64>();
            // Egress: one 512-bit line per row of lane buffers, padded.
            for row in 0..max_lane {
                for lane in lanes.iter() {
                    out_words.push(*lane.get(row).unwrap_or(&DUMMY));
                }
            }
            out_lines += max_lane as u64;
        }
        self.job.output.write_u32s(mem, 0, &out_words);
        (total_matches, out_lines)
    }
}

impl Engine for SelectionEngine {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> String {
        format!("selection[base={}]", self.job.index_base)
    }

    fn next_phase(&mut self, mem: &mut HbmMemory) -> Option<Phase> {
        self.run_functional(mem);
        self.phase.take()
    }

    fn functional_ranges(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::with_capacity(4);
        out.extend(self.job.input.ranges());
        out.extend(self.job.output.ranges());
        out
    }

    fn run_functional(&mut self, mem: &mut dyn MemBytes) {
        if self.prepared {
            return;
        }
        self.prepared = true;
        let (matches, out_lines) = self.scan(mem);
        self.matches = matches;
        self.out_bytes = out_lines * LINE_BYTES;

        let in_bytes = self.job.items * 4;
        let n_switches =
            (self.job.items as f64 / (BUFFER_SIZE * PARALLELISM) as f64).ceil();
        let overhead =
            cycles_to_secs(&self.cfg, n_switches * SWITCH_OVERHEAD_CYCLES);
        let out_ratio = self.out_bytes as f64 / in_bytes.max(1) as f64;
        // Ingress paced by input bytes; egress traffic rides along
        // at `out_ratio` bytes per input byte on the same port.
        let mut phase = Phase::new("scan", in_bytes)
            .with_buffer(&self.job.input, 0, 1.0)
            .with_overhead(overhead);
        if out_ratio > 0.0 {
            phase = phase.with_buffer(&self.job.output, 2, out_ratio);
        }
        self.phase = Some(phase);
    }
}

/// Decode a padded result buffer back into the compacted index list
/// (what the DBMS does after copying results to host memory).
pub fn compact_results(mem: &HbmMemory, out: &ShimBuffer, out_bytes: u64) -> Vec<u32> {
    let words = out.read_u32s(mem, 0, (out_bytes / 4) as usize);
    words.into_iter().filter(|&w| w != DUMMY).collect()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::engines::sim;
    use crate::hbm::config::FabricClock;
    use crate::hbm::shim::Shim;
    use crate::util::rng::Xoshiro256;

    fn setup(items: u64) -> (HbmConfig, HbmMemory, Shim) {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mem = HbmMemory::new();
        let shim = Shim::new(cfg.clone());
        let _ = items;
        (cfg, mem, shim)
    }

    fn run_one(
        items: u64,
        lo: u32,
        hi: u32,
        data: &[u32],
    ) -> (sim::SimReport, u64, Vec<u32>, u64) {
        let (cfg, mut mem, mut shim) = setup(items);
        let input = shim.alloc(0, items * 4).unwrap();
        let output = shim.alloc(0, items * 4 + 64).unwrap();
        input.write_u32s(&mut mem, 0, data);
        let job = SelectionJob { input, items, index_base: 0, lo, hi, output };
        let mut engines: Vec<Box<dyn Engine>> =
            vec![Box::new(SelectionEngine::new(cfg.clone(), job))];
        let report = sim::run(&cfg, &mut mem, &mut engines);
        // Recover engine fields via a fresh functional pass for assertions.
        let mut probe = SelectionEngine::new(
            cfg.clone(),
            SelectionJob { input, items, index_base: 0, lo, hi, output },
        );
        let (matches, out_lines) = probe.scan(&mut mem);
        let idx = compact_results(&mem, &output, out_lines * 64);
        (report, matches, idx, out_lines * 64)
    }

    #[test]
    fn finds_exactly_the_in_range_indexes() {
        let data: Vec<u32> = (0..1000u32).collect();
        let (_, matches, idx, _) = run_one(1000, 100, 199, &data);
        assert_eq!(matches, 100);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (100..200).collect::<Vec<u32>>());
    }

    #[test]
    fn zero_selectivity_produces_no_output() {
        let data: Vec<u32> = vec![5; 100_000];
        let (_, matches, idx, out_bytes) = run_one(100_000, 100, 200, &data);
        assert_eq!(matches, 0);
        assert!(idx.is_empty());
        assert_eq!(out_bytes, 0);
    }

    #[test]
    fn full_selectivity_output_equals_input_size() {
        let data: Vec<u32> = (0..64_000u32).collect();
        let (_, matches, _, out_bytes) = run_one(64_000, 0, u32::MAX, &data);
        assert_eq!(matches, 64_000);
        // All lanes fill evenly → no padding: output bytes == input bytes.
        assert_eq!(out_bytes, 64_000 * 4);
    }

    #[test]
    fn padding_overhead_is_bounded() {
        // Random 10% selectivity: padded output exceeds matches, but by a
        // bounded factor (lane imbalance within 1024-line chunks).
        let mut rng = Xoshiro256::new(1);
        let data: Vec<u32> =
            (0..1_000_000).map(|_| rng.next_u32() % 1000).collect();
        let (_, matches, idx, out_bytes) = run_one(1_000_000, 0, 99, &data);
        assert!(matches > 80_000 && matches < 120_000, "matches={matches}");
        assert_eq!(idx.len() as u64, matches);
        let padded_items = out_bytes / 4;
        assert!(padded_items >= matches);
        assert!(
            (padded_items as f64) < matches as f64 * 1.25,
            "padding blowup: {padded_items} vs {matches}"
        );
    }

    #[test]
    fn single_engine_rate_matches_paper_11gbs() {
        // Fig. 5: 11 GB/s per engine at 0% selectivity (200 MHz).
        let items = 8_000_000u64;
        let data: Vec<u32> = vec![0; items as usize];
        let (report, ..) = run_one(items, 100, 200, &data);
        let rate = (items * 4) as f64 / report.makespan / 1e9;
        assert!((rate - 11.0).abs() < 0.3, "rate={rate}");
    }

    #[test]
    fn high_selectivity_roughly_halves_consumption() {
        // Fig. 6: at 100% selectivity the port is shared between reads and
        // writes → input consumption drops to ~half.
        let items = 4_000_000u64;
        let data: Vec<u32> = (0..items as u32).collect();
        let (r0, ..) = run_one(items, u32::MAX, u32::MAX, &data); // 0%
        let (r100, ..) = run_one(items, 0, u32::MAX, &data); // 100%
        let ratio = r100.makespan / r0.makespan;
        assert!((ratio - 2.0).abs() < 0.15, "ratio={ratio}");
    }

    #[test]
    fn fourteen_engines_reach_fig5_aggregate() {
        // Fig. 5a: 154 GB/s with 14 engines on ideally partitioned data.
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(cfg.clone());
        let per_engine = 2_000_000u64;
        let mut engines: Vec<Box<dyn Engine>> = Vec::new();
        for e in 0..14usize {
            let input = shim.alloc(e, per_engine * 4).unwrap();
            let output = shim.alloc(e, per_engine * 4 + 64).unwrap();
            input.write_u32s(&mut mem, 0, &vec![0u32; per_engine as usize]);
            engines.push(Box::new(SelectionEngine::new(
                cfg.clone(),
                SelectionJob {
                    input,
                    items: per_engine,
                    index_base: (e as u32) * per_engine as u32,
                    lo: 1,
                    hi: 2,
                    output,
                },
            )));
        }
        let report = sim::run(&cfg, &mut mem, &mut engines);
        let rate = (14 * per_engine * 4) as f64 / report.makespan / 1e9;
        assert!((rate - 154.0).abs() < 4.0, "aggregate rate={rate}");
    }
}
