//! Event-driven fluid simulation of concurrent engines over the HBM.
//!
//! Between events (phase completions) the set of active flows is constant,
//! so the max-min allocation from [`crate::hbm::fluid`] is constant too;
//! the simulator advances directly to the earliest completion. Runtime is
//! O(#phases × solve-cost), independent of data volume — a 2 GB join and
//! a 2 KB join cost the same to *time* (the functional work still touches
//! the real bytes).
//!
//! ## Parallel functional execution, serial timing
//!
//! Engines within a round are independent: they read and write disjoint
//! `ShimBuffer` ranges in their own ports' home windows. [`run`] exploits
//! that by executing every engine's *functional* pass (the scan/probe/SGD
//! loops over real bytes — the host-side cost that dominates large runs)
//! on `std::thread::scope` workers first, each against a disjoint
//! [`HbmView`](crate::hbm::HbmView) carved out of the page store, and
//! only then runs the (cheap, deterministic) event-driven timing loop
//! single-threaded. Results are bit-identical to serial execution: each
//! engine touches only its own pages, the views merge back
//! deterministically, and the timing loop consumes the same phase
//! sequence either way. Engines that do not declare their memory
//! footprint ([`Engine::functional_ranges`] empty), or whose declared
//! ranges overlap, fall back to serial functional execution —
//! correctness never depends on the parallel path.

use super::{Engine, EngineStats, Phase};
use crate::hbm::fluid::{solve, Flow};
use crate::hbm::memory::HbmMemory;
use crate::hbm::HbmConfig;

struct ActivePhase {
    engine_idx: usize,
    phase: Phase,
    /// Progress through `work_bytes`, in bytes.
    done_bytes: f64,
    /// Remaining fixed overhead to burn before/alongside progress.
    overhead_left: f64,
}

/// Result of a simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Time at which the last engine finished (seconds).
    pub makespan: f64,
    pub engines: Vec<EngineStats>,
}

impl SimReport {
    /// Aggregate processing rate given total useful bytes, in bytes/s.
    pub fn rate(&self, useful_bytes: u64) -> f64 {
        useful_bytes as f64 / self.makespan.max(1e-12)
    }
}

/// Run all engines to completion, sharing `mem` and the crossbar, with
/// the functional passes executed on parallel worker threads when the
/// engines' declared footprints are disjoint (see the module docs).
pub fn run(cfg: &HbmConfig, mem: &mut HbmMemory, engines: &mut [Box<dyn Engine>]) -> SimReport {
    run_mode(cfg, mem, engines, true)
}

/// [`run`] with the functional passes forced onto the calling thread —
/// the serial reference for callers driving the simulator directly. (The
/// coordinator's equivalent switch is
/// `Coordinator::set_parallel_functional(false)`, which is what
/// `hbmctl bench-host` and the determinism suite use.)
pub fn run_serial(
    cfg: &HbmConfig,
    mem: &mut HbmMemory,
    engines: &mut [Box<dyn Engine>],
) -> SimReport {
    run_mode(cfg, mem, engines, false)
}

/// Below this total declared footprint, per-round thread-spawn overhead
/// outweighs the parallel win; such rounds run serially so the default
/// mode is never slower than serial on small workloads.
const PARALLEL_MIN_FOOTPRINT_BYTES: u64 = 1 << 20;

/// Execute every engine's functional pass up front. Parallel when
/// requested and worthwhile (≥ 2 engines, a host with > 1 core, every
/// footprint declared, all footprints page-disjoint, and enough total
/// work to amortize the worker threads); serial otherwise. Either way,
/// engines are *prepared* afterwards: `next_phase` only emits
/// precomputed phases.
fn prepare_functional(mem: &mut HbmMemory, engines: &mut [Box<dyn Engine>], parallel: bool) {
    let want_parallel = parallel
        && engines.len() > 1
        && std::thread::available_parallelism().map(|p| p.get() > 1).unwrap_or(false);
    if want_parallel {
        let range_sets: Vec<Vec<(u64, u64)>> =
            engines.iter().map(|e| e.functional_ranges()).collect();
        let footprint: u64 = range_sets
            .iter()
            .flat_map(|set| set.iter().map(|&(_, bytes)| bytes))
            .sum();
        if footprint >= PARALLEL_MIN_FOOTPRINT_BYTES
            && range_sets.iter().all(|r| !r.is_empty())
        {
            if let Some(views) = mem.take_disjoint_views(&range_sets) {
                let views = std::thread::scope(|scope| {
                    let workers: Vec<_> = engines
                        .iter_mut()
                        .zip(views)
                        .map(|(engine, mut view)| {
                            scope.spawn(move || {
                                engine.run_functional(&mut view);
                                view
                            })
                        })
                        .collect();
                    workers
                        .into_iter()
                        .map(|w| w.join().expect("engine functional worker panicked"))
                        .collect::<Vec<_>>()
                });
                mem.restore_views(views);
                return;
            }
        }
    }
    for engine in engines.iter_mut() {
        engine.run_functional(mem);
    }
}

/// Run all engines to completion, with explicit control over whether the
/// functional passes use worker threads.
pub fn run_mode(
    cfg: &HbmConfig,
    mem: &mut HbmMemory,
    engines: &mut [Box<dyn Engine>],
    parallel: bool,
) -> SimReport {
    let n = engines.len();
    prepare_functional(mem, engines, parallel);
    let mut stats: Vec<EngineStats> = engines
        .iter()
        .map(|e| EngineStats { name: e.name(), ..Default::default() })
        .collect();

    let mut active: Vec<Option<ActivePhase>> = Vec::with_capacity(n);
    for (i, e) in engines.iter_mut().enumerate() {
        active.push(e.next_phase(mem).map(|p| ActivePhase {
            engine_idx: i,
            overhead_left: p.fixed_overhead,
            phase: p,
            done_bytes: 0.0,
        }));
        if active[i].is_some() {
            stats[i].phases += 1;
        }
    }

    let mut now = 0.0f64;
    let mut guard = 0u64;
    loop {
        guard += 1;
        assert!(guard < 50_000_000, "simulation did not terminate");

        // Collect flows from all active phases. Apply the phase's compute
        // cap to each of its flows so the solver can hand slack to others.
        let mut flows: Vec<Flow> = Vec::new();
        let mut flow_owner: Vec<(usize, f64)> = Vec::new(); // (phase idx, per_unit)
        let mut any_active = false;
        for (pi, ap) in active.iter().enumerate() {
            let Some(ap) = ap else { continue };
            any_active = true;
            for pf in &ap.phase.flows {
                let mut f = pf.flow.clone();
                f.id = flows.len();
                // Weighted max-min: a phase's flows advance in lock-step,
                // each demanding bandwidth proportional to its per-unit
                // share (an idle-ish egress flow must not hoard half the
                // segment).
                f.weight = pf.per_unit.max(1e-9);
                if ap.phase.rate_cap.is_finite() {
                    f.rate_cap = f.rate_cap.min(ap.phase.rate_cap * pf.per_unit);
                }
                flows.push(f);
                flow_owner.push((pi, pf.per_unit));
            }
        }
        if !any_active {
            break;
        }

        let alloc = solve(cfg, &flows);

        // Phase progress rate: slowest flow relative to its per-unit share;
        // compute-only phases progress at their cap (or instantly if pure
        // overhead).
        let mut phase_rate = vec![f64::INFINITY; n];
        for (fi, &(pi, per_unit)) in flow_owner.iter().enumerate() {
            if per_unit > 1e-12 {
                phase_rate[pi] = phase_rate[pi].min(alloc.rates[fi] / per_unit);
            }
        }
        for (pi, ap) in active.iter().enumerate() {
            if let Some(ap) = ap {
                if phase_rate[pi].is_infinite() {
                    // No HBM flows: pure compute phase.
                    phase_rate[pi] = ap.phase.rate_cap;
                }
            }
        }

        // Time to the next completion. Overhead burns first, then work.
        let mut dt = f64::INFINITY;
        for (pi, ap) in active.iter().enumerate() {
            let Some(ap) = ap else { continue };
            let mut t = ap.overhead_left;
            let remaining = ap.phase.work_bytes as f64 - ap.done_bytes;
            if remaining > 1e-9 {
                let r = phase_rate[pi];
                t += if r.is_finite() && r > 0.0 { remaining / r } else { f64::INFINITY };
            }
            dt = dt.min(t);
        }
        assert!(dt.is_finite(), "active phase can make no progress");
        // Numerical floor keeps degenerate zero-work phases moving.
        let dt = dt.max(1e-15);
        now += dt;

        // Advance all phases by dt; retire completed ones.
        for pi in 0..n {
            let Some(ap) = active[pi].as_mut() else { continue };
            let mut t = dt;
            if ap.overhead_left > 0.0 {
                let burn = ap.overhead_left.min(t);
                ap.overhead_left -= burn;
                t -= burn;
            }
            if t > 0.0 && phase_rate[pi].is_finite() {
                let adv = phase_rate[pi] * t;
                ap.done_bytes += adv;
                // Account HBM bytes moved.
                let per_unit_total: f64 =
                    ap.phase.flows.iter().map(|f| f.per_unit).sum();
                stats[ap.engine_idx].hbm_bytes += (adv * per_unit_total) as u64;
            }
            let finished = ap.overhead_left <= 1e-15
                && ap.done_bytes + 1e-6 >= ap.phase.work_bytes as f64;
            if finished {
                let ei = ap.engine_idx;
                stats[ei].finish_time = now;
                active[pi] = engines[ei].next_phase(mem).map(|p| ActivePhase {
                    engine_idx: ei,
                    overhead_left: p.fixed_overhead,
                    phase: p,
                    done_bytes: 0.0,
                });
                if active[pi].is_some() {
                    stats[ei].phases += 1;
                }
            }
        }
    }

    SimReport { makespan: now, engines: stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::config::FabricClock;
    use crate::util::units::MIB;

    /// Test engine: streams `total` bytes from a fixed range in one phase.
    struct Streamer {
        addr: u64,
        total: u64,
        cap: f64,
        emitted: bool,
    }

    impl Engine for Streamer {
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }

        fn name(&self) -> String {
            format!("streamer@{:#x}", self.addr)
        }
        fn next_phase(&mut self, _mem: &mut HbmMemory) -> Option<Phase> {
            if self.emitted {
                return None;
            }
            self.emitted = true;
            Some(
                Phase::new("stream", self.total)
                    .with_flow(Flow::new(0, self.addr, 256 * MIB), 1.0)
                    .with_rate_cap(self.cap),
            )
        }
    }

    fn streamer(addr: u64, total: u64, cap: f64) -> Box<dyn Engine> {
        Box::new(Streamer { addr, total, cap, emitted: false })
    }

    #[test]
    fn single_engine_runs_at_port_rate() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 512 * MIB;
        let mut engines = vec![streamer(0, total, f64::INFINITY)];
        let r = run(&cfg, &mut mem, &mut engines);
        let expect = total as f64 / cfg.port_effective();
        assert!((r.makespan / expect - 1.0).abs() < 1e-6);
        assert_eq!(r.engines[0].hbm_bytes, total);
    }

    #[test]
    fn separated_engines_overlap_perfectly() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 256 * MIB;
        let mut engines: Vec<Box<dyn Engine>> = (0..4)
            .map(|i| streamer(i * 256 * MIB, total, f64::INFINITY))
            .collect();
        let r = run(&cfg, &mut mem, &mut engines);
        let expect = total as f64 / cfg.port_effective();
        assert!((r.makespan / expect - 1.0).abs() < 1e-6, "no slowdown expected");
    }

    #[test]
    fn contending_engines_halve() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 256 * MIB;
        let mut engines: Vec<Box<dyn Engine>> =
            (0..2).map(|_| streamer(0, total, f64::INFINITY)).collect();
        let r = run(&cfg, &mut mem, &mut engines);
        let expect = 2.0 * total as f64 / cfg.segment_capacity();
        assert!((r.makespan / expect - 1.0).abs() < 1e-6);
    }

    #[test]
    fn compute_cap_binds() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 100 * MIB;
        let cap = 1e9;
        let mut engines = vec![streamer(0, total, cap)];
        let r = run(&cfg, &mut mem, &mut engines);
        assert!((r.makespan - total as f64 / cap).abs() / r.makespan < 1e-6);
    }

    #[test]
    fn capped_engine_releases_bandwidth() {
        // One capped + one uncapped engine on the same segment: the
        // uncapped one should get segment_capacity - cap.
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 256 * MIB;
        let cap = 1e9;
        let mut engines =
            vec![streamer(0, total, cap), streamer(0, total, f64::INFINITY)];
        let r = run(&cfg, &mut mem, &mut engines);
        // Fast engine rate = seg - 1 GB/s; finishes first. Then slow one
        // continues at its cap.
        let fast_rate = cfg.segment_capacity() - cap;
        let t_fast = total as f64 / fast_rate;
        assert!(
            (r.engines[1].finish_time / t_fast - 1.0).abs() < 1e-3,
            "fast={} expect={}",
            r.engines[1].finish_time,
            t_fast
        );
        let t_slow = total as f64 / cap;
        assert!((r.engines[0].finish_time / t_slow - 1.0).abs() < 1e-3);
    }

    #[test]
    fn multi_phase_engine_completes_all_phases() {
        struct TwoPhase {
            left: u32,
        }
        impl Engine for TwoPhase {
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }

            fn name(&self) -> String {
                "twophase".into()
            }
            fn next_phase(&mut self, _m: &mut HbmMemory) -> Option<Phase> {
                if self.left == 0 {
                    return None;
                }
                self.left -= 1;
                Some(
                    Phase::new("p", MIB)
                        .with_flow(Flow::new(0, 0, MIB), 1.0),
                )
            }
        }
        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut engines: Vec<Box<dyn Engine>> = vec![Box::new(TwoPhase { left: 3 })];
        let r = run(&cfg, &mut mem, &mut engines);
        assert_eq!(r.engines[0].phases, 3);
        assert_eq!(r.engines[0].hbm_bytes, 3 * MIB);
    }

    #[test]
    fn overhead_only_phase_advances_time() {
        struct Sleeper(bool);
        impl Engine for Sleeper {
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }

            fn name(&self) -> String {
                "sleeper".into()
            }
            fn next_phase(&mut self, _m: &mut HbmMemory) -> Option<Phase> {
                if self.0 {
                    return None;
                }
                self.0 = true;
                Some(Phase::compute("sleep", 1e-3))
            }
        }
        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut engines: Vec<Box<dyn Engine>> = vec![Box::new(Sleeper(false))];
        let r = run(&cfg, &mut mem, &mut engines);
        assert!((r.makespan - 1e-3).abs() < 1e-9);
    }
}
