//! Event-driven fluid simulation of concurrent engines over the HBM.
//!
//! Between events (phase or transfer completions) the set of active flows
//! is constant, so the max-min allocation from [`crate::hbm::fluid`] is
//! constant too; the simulator advances directly to the earliest
//! completion. Runtime is O(#phases × solve-cost), independent of data
//! volume — a 2 GB join and a 2 KB join cost the same to *time* (the
//! functional work still touches the real bytes).
//!
//! ## The persistent session
//!
//! [`SimSession`] is the card's continuous timeline: engines — and
//! modeled host-link transfers for copy-in/copy-out — **join and leave at
//! arbitrary event times**. The coordinator keeps one session alive for
//! its whole life, so one job's copy-in overlaps other jobs' compute, a
//! job's engines start the moment its own transfer lands, and a finished
//! job's slots free at its own completion event instead of a round
//! barrier. [`run`]/[`run_mode`] remain the one-shot convenience: they
//! drive a private session from `t = 0` to drain, which is exactly the
//! old round-scoped behaviour (and keeps the Fig. 2 anchors untouched).
//!
//! Per event the session solves the crossbar allocation over every active
//! phase's flows (link transfers share a separate host-link resource
//! max-min, like the OpenCAPI model), advances to the earliest
//! completion, and reports [`SimEvent`]s. Segment weights are cached per
//! phase and the solver runs on reusable scratch buffers
//! ([`crate::hbm::fluid::solve_in`]), so steady-state events perform no
//! heap allocation.
//!
//! ## Parallel functional execution, serial timing
//!
//! Engines joining together are independent: they read and write disjoint
//! `ShimBuffer` ranges in their own ports' home windows.
//! [`prepare_functional`] exploits that by executing every engine's
//! *functional* pass (the scan/probe/SGD loops over real bytes — the
//! host-side cost that dominates large runs) on `std::thread::scope`
//! workers first, each against a disjoint
//! [`HbmView`](crate::hbm::HbmView) carved out of the page store; the
//! (cheap, deterministic) event-driven timing loop stays single-threaded.
//! Results are bit-identical to serial execution: each engine touches
//! only its own pages, the views merge back deterministically, and the
//! timing loop consumes the same phase sequence either way. Engines that
//! do not declare their memory footprint
//! ([`Engine::functional_ranges`] empty), or whose declared ranges
//! overlap, fall back to serial functional execution — correctness never
//! depends on the parallel path.

use super::{Engine, EngineStats, Phase};
use crate::hbm::fluid::{solve_in, Flow, SolveScratch};
use crate::hbm::memory::{HbmMemory, PAGE_BYTES};
use crate::hbm::HbmConfig;
use crate::hbm::MemBytes;
use crate::trace::{Event, Tracer};

struct ActivePhase {
    phase: Phase,
    /// Progress through `work_bytes`, in bytes.
    done_bytes: f64,
    /// Remaining fixed overhead to burn before/alongside progress.
    overhead_left: f64,
    /// Segment weights of each phase flow, computed once when the phase
    /// starts (they depend only on the flow's address range) and copied
    /// into the solver's flat table per event — no per-event `Vec`s.
    flow_weights: Vec<Vec<(usize, f64)>>,
}

impl ActivePhase {
    fn new(phase: Phase) -> Self {
        let flow_weights =
            phase.flows.iter().map(|pf| pf.flow.segment_weights()).collect();
        Self {
            overhead_left: phase.fixed_overhead,
            done_bytes: 0.0,
            flow_weights,
            phase,
        }
    }
}

/// One engine participating in the session.
struct Member {
    /// Taken out by [`SimSession::take_engine`] after the engine is done.
    engine: Option<Box<dyn Engine>>,
    active: Option<ActivePhase>,
    stats: EngineStats,
}

/// One modeled host-link transfer (copy-in or copy-out) sharing the
/// session's link bandwidth max-min with every other active transfer.
struct Transfer {
    latency_left: f64,
    remaining_bytes: f64,
    done: bool,
}

/// A completion the session reports from [`SimSession::advance`]: the
/// join/leave points the scheduler reacts to. Internal phase transitions
/// of a multi-phase engine are not events — nothing external can change
/// between them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEvent {
    /// The engine behind this member id emitted its last phase.
    EngineDone { member: usize },
    /// The transfer behind this id finished moving its bytes.
    TransferDone { transfer: usize },
}

/// Result of a one-shot simulation run ([`run`]/[`run_mode`]).
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Time at which the last engine finished (seconds).
    pub makespan: f64,
    pub engines: Vec<EngineStats>,
    /// How the functional passes actually executed — the ground truth
    /// the static analyzer's parallelism pass predicts.
    pub functional: FunctionalMode,
}

impl SimReport {
    /// Aggregate processing rate given total useful bytes, in bytes/s.
    pub fn rate(&self, useful_bytes: u64) -> f64 {
        useful_bytes as f64 / self.makespan.max(1e-12)
    }
}

/// The persistent event-driven card timeline. See the module docs.
pub struct SimSession {
    cfg: HbmConfig,
    now: f64,
    members: Vec<Member>,
    transfers: Vec<Transfer>,
    /// Host-link bandwidth shared max-min among active transfers.
    /// `INFINITY` (the default) makes transfers pure-latency.
    link_bandwidth: f64,
    /// Seconds with ≥ 1 active transfer.
    link_busy: f64,
    /// Seconds with ≥ 1 active transfer *and* ≥ 1 active engine phase —
    /// the compute/transfer overlap the continuous scheduler buys.
    overlap: f64,
    /// Member slots whose engine was reclaimed ([`SimSession::take_engine`]),
    /// recycled by the next [`SimSession::add_engine`] so a long-lived
    /// session's member table stays bounded by *peak concurrency*, not by
    /// total jobs served. Safe because a taken member's events were all
    /// delivered before its slot could free.
    free_members: Vec<usize>,
    // Reusable per-event buffers (see the module docs on allocation).
    scratch: SolveScratch,
    flows: Vec<Flow>,
    flow_owner: Vec<(usize, f64)>,
    weight_flat: Vec<(usize, f64)>,
    weight_spans: Vec<(usize, usize)>,
    phase_rate: Vec<f64>,
}

impl SimSession {
    pub fn new(cfg: HbmConfig) -> Self {
        Self {
            cfg,
            now: 0.0,
            members: Vec::new(),
            transfers: Vec::new(),
            link_bandwidth: f64::INFINITY,
            link_busy: 0.0,
            overlap: 0.0,
            free_members: Vec::new(),
            scratch: SolveScratch::new(),
            flows: Vec::new(),
            flow_owner: Vec::new(),
            weight_flat: Vec::new(),
            weight_spans: Vec::new(),
            phase_rate: Vec::new(),
        }
    }

    /// Current simulated time (seconds since session start).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Host-link bandwidth for transfers, bytes/s.
    pub fn set_link_bandwidth(&mut self, bw: f64) {
        self.link_bandwidth = bw;
    }

    /// Swap the timing configuration. Whole-card semantics: in-flight
    /// phases see the new crossbar rates from the next event on.
    pub fn set_config(&mut self, cfg: HbmConfig) {
        self.cfg = cfg;
    }

    /// Seconds the host link spent moving bytes.
    pub fn link_busy_seconds(&self) -> f64 {
        self.link_busy
    }

    /// Seconds a transfer and an engine phase were simultaneously active.
    pub fn overlap_seconds(&self) -> f64 {
        self.overlap
    }

    /// Nothing left to simulate: no active engine phase, no transfer.
    pub fn idle(&self) -> bool {
        self.members.iter().all(|m| m.active.is_none())
            && self.transfers.iter().all(|t| t.done)
    }

    /// Fast-forward an idle session (e.g. after externally-timed
    /// round-barrier work advanced the card clock past the session).
    pub fn sync_now(&mut self, t: f64) {
        assert!(self.idle(), "cannot fast-forward a busy session");
        if t > self.now {
            self.now = t;
        }
    }

    /// Join an engine at the current time. The engine should already be
    /// *prepared* (see [`prepare_functional`]); unprepared engines run
    /// their functional pass lazily inside `next_phase`, exactly like the
    /// historical single-threaded drivers. Returns the member id and
    /// whether the engine actually has work (an engine whose first
    /// `next_phase` is `None` joins already-done and emits no event).
    pub fn add_engine(
        &mut self,
        mut engine: Box<dyn Engine>,
        mem: &mut HbmMemory,
    ) -> (usize, bool) {
        let mut stats = EngineStats { name: engine.name(), ..Default::default() };
        let active = engine.next_phase(mem).map(ActivePhase::new);
        let has_work = active.is_some();
        if has_work {
            stats.phases += 1;
        }
        let member = Member { engine: Some(engine), active, stats };
        let id = match self.free_members.pop() {
            Some(slot) => {
                self.members[slot] = member;
                slot
            }
            None => {
                self.members.push(member);
                self.members.len() - 1
            }
        };
        (id, has_work)
    }

    /// Start a host-link transfer of `bytes` at the current time, with a
    /// fixed `latency` burned before (and alongside) the bytes.
    pub fn add_transfer(&mut self, bytes: u64, latency: f64) -> usize {
        let id = self.transfers.len();
        self.transfers.push(Transfer {
            latency_left: latency,
            remaining_bytes: bytes as f64,
            done: false,
        });
        id
    }

    /// A done member's accumulated statistics.
    pub fn engine_stats(&self, member: usize) -> &EngineStats {
        &self.members[member].stats
    }

    /// Reclaim a done engine (for result downcasting) and its stats,
    /// freeing the member slot for reuse. Panics if the engine still has
    /// phases or was already taken.
    pub fn take_engine(&mut self, member: usize) -> (Box<dyn Engine>, EngineStats) {
        let m = &mut self.members[member];
        assert!(m.active.is_none(), "cannot take a running engine");
        let Some(engine) = m.engine.take() else {
            panic!("engine already taken")
        };
        self.free_members.push(member);
        (engine, m.stats.clone())
    }

    /// Abort a member's engine at the current time (fault injection):
    /// the in-flight phase, if any, is dropped on the floor and the
    /// engine box is discarded — its partial functional results are
    /// unrecoverable by design, a retry re-dispatches from scratch.
    /// HBM bytes the aborted phase already moved stay accounted
    /// (pro-rated on `done_bytes`), so chaos statistics see the wasted
    /// traffic. The member slot frees for reuse and no further event is
    /// emitted for it. Also accepts members whose engine already
    /// finished but was not yet taken (a killed job's done co-members).
    /// Panics if the engine was already taken.
    pub fn abort_engine(&mut self, member: usize) -> EngineStats {
        let m = &mut self.members[member];
        assert!(m.engine.is_some(), "cannot abort a taken engine");
        if let Some(ap) = m.active.take() {
            let per_unit_total: f64 = ap.phase.flows.iter().map(|f| f.per_unit).sum();
            m.stats.hbm_bytes += (ap.done_bytes * per_unit_total).round() as u64;
        }
        m.engine = None;
        m.stats.finish_time = self.now;
        let stats = m.stats.clone();
        self.free_members.push(member);
        stats
    }

    /// Abort an in-flight transfer at the current time (fault
    /// injection): it stops consuming link bandwidth from the next
    /// event on and never emits [`SimEvent::TransferDone`]. Link-busy
    /// and overlap seconds accrued while it ran stay accounted — they
    /// accrue per inter-event interval, so a truncated transfer span
    /// covering exactly its active window keeps the trace validator's
    /// link-busy union identity. Panics if the transfer already
    /// completed.
    pub fn abort_transfer(&mut self, transfer: usize) {
        let t = &mut self.transfers[transfer];
        assert!(!t.done, "cannot abort a finished transfer");
        t.done = true;
    }

    /// Advance to the next completion event(s). Returns every
    /// [`SimEvent`] landing at the new `now` — at least one, unless the
    /// session is idle (empty return). Internal phase hand-offs of
    /// multi-phase engines are processed silently.
    pub fn advance(&mut self, mem: &mut HbmMemory) -> Vec<SimEvent> {
        let mut tracer = Tracer::disabled();
        self.advance_traced(mem, &mut tracer)
    }

    /// [`advance`](Self::advance) with bandwidth sampling: when `tracer`
    /// is enabled, every inter-event interval emits one
    /// [`Event::Bandwidth`] per active member (the HBM bytes/s the fluid
    /// solver allocated to its phase over `[t, t + dt]`) and one
    /// [`Event::LinkRate`] for the aggregate host-link allocation. With a
    /// disabled tracer this *is* `advance` — the sampling block is
    /// guarded by the one-word enabled check, so the steady-state path
    /// stays allocation-free.
    pub fn advance_traced(
        &mut self,
        mem: &mut HbmMemory,
        tracer: &mut Tracer,
    ) -> Vec<SimEvent> {
        let mut events = Vec::new();
        let mut guard = 0u64;
        while events.is_empty() {
            guard += 1;
            assert!(guard < 50_000_000, "simulation did not terminate");

            // Collect flows from all active phases, with each phase's
            // cached segment weights copied into the solver's flat table.
            // Apply the phase's compute cap to each of its flows so the
            // solver can hand slack to others.
            self.flows.clear();
            self.flow_owner.clear();
            self.weight_flat.clear();
            self.weight_spans.clear();
            let mut any_engine = false;
            for (mi, m) in self.members.iter().enumerate() {
                let Some(ap) = &m.active else { continue };
                any_engine = true;
                for (fi, pf) in ap.phase.flows.iter().enumerate() {
                    let mut f = pf.flow.clone();
                    f.id = self.flows.len();
                    // Weighted max-min: a phase's flows advance in
                    // lock-step, each demanding bandwidth proportional to
                    // its per-unit share (an idle-ish egress flow must
                    // not hoard half the segment).
                    f.weight = pf.per_unit.max(1e-9);
                    if ap.phase.rate_cap.is_finite() {
                        f.rate_cap = f.rate_cap.min(ap.phase.rate_cap * pf.per_unit);
                    }
                    let w = &ap.flow_weights[fi];
                    self.weight_spans.push((self.weight_flat.len(), w.len()));
                    self.weight_flat.extend_from_slice(w);
                    self.flows.push(f);
                    self.flow_owner.push((mi, pf.per_unit));
                }
            }
            let n_transfers = self.transfers.iter().filter(|t| !t.done).count();
            if !any_engine && n_transfers == 0 {
                return events; // idle
            }

            solve_in(
                &self.cfg,
                &self.flows,
                &self.weight_spans,
                &self.weight_flat,
                &mut self.scratch,
            );

            // Phase progress rate: slowest flow relative to its per-unit
            // share; compute-only phases progress at their cap (or
            // instantly if pure overhead).
            self.phase_rate.clear();
            self.phase_rate.resize(self.members.len(), f64::INFINITY);
            for (fi, &(mi, per_unit)) in self.flow_owner.iter().enumerate() {
                if per_unit > 1e-12 {
                    self.phase_rate[mi] =
                        self.phase_rate[mi].min(self.scratch.rates[fi] / per_unit);
                }
            }
            for (mi, m) in self.members.iter().enumerate() {
                if let Some(ap) = &m.active {
                    if self.phase_rate[mi].is_infinite() {
                        // No HBM flows: pure compute phase.
                        self.phase_rate[mi] = ap.phase.rate_cap;
                    }
                }
            }

            // Active transfers split the host link evenly (max-min with
            // equal weights and no caps collapses to an even split).
            let link_rate = if n_transfers > 0 {
                self.link_bandwidth / n_transfers as f64
            } else {
                0.0
            };

            // Time to the next completion. Overhead/latency burns first,
            // then work.
            let mut dt = f64::INFINITY;
            for (mi, m) in self.members.iter().enumerate() {
                let Some(ap) = &m.active else { continue };
                let mut t = ap.overhead_left;
                let remaining = ap.phase.work_bytes as f64 - ap.done_bytes;
                if remaining > 1e-9 {
                    let r = self.phase_rate[mi];
                    t += if r.is_finite() && r > 0.0 {
                        remaining / r
                    } else {
                        f64::INFINITY
                    };
                }
                dt = dt.min(t);
            }
            for tr in &self.transfers {
                if tr.done {
                    continue;
                }
                let mut t = tr.latency_left;
                if tr.remaining_bytes > 1e-6 {
                    t += if link_rate > 0.0 && link_rate.is_finite() {
                        tr.remaining_bytes / link_rate
                    } else if link_rate.is_infinite() {
                        0.0
                    } else {
                        f64::INFINITY
                    };
                }
                dt = dt.min(t);
            }
            assert!(dt.is_finite(), "active phase can make no progress");
            // Numerical floor keeps degenerate zero-work phases moving.
            let dt = dt.max(1e-15);
            if tracer.is_enabled() {
                // Fluid-solver bandwidth samples over [now, now + dt]:
                // one per active member (its flows' allocated rates
                // summed) plus the aggregate link allocation.
                let t0 = self.now;
                for (mi, m) in self.members.iter().enumerate() {
                    if m.active.is_none() {
                        continue;
                    }
                    let bw: f64 = self
                        .flow_owner
                        .iter()
                        .enumerate()
                        .filter(|&(_, &(owner, _))| owner == mi)
                        .map(|(fi, _)| self.scratch.rates[fi])
                        .sum();
                    tracer.record(|| Event::Bandwidth {
                        t: t0,
                        dt,
                        member: mi,
                        bytes_per_sec: bw,
                    });
                }
                if n_transfers > 0 && link_rate.is_finite() {
                    tracer.record(|| Event::LinkRate {
                        t: t0,
                        dt,
                        transfers: n_transfers,
                        bytes_per_sec: link_rate * n_transfers as f64,
                    });
                }
            }
            self.now += dt;
            if n_transfers > 0 {
                self.link_busy += dt;
                if any_engine {
                    self.overlap += dt;
                }
            }

            // Advance all phases by dt; retire completed ones.
            for mi in 0..self.members.len() {
                let m = &mut self.members[mi];
                let Some(ap) = m.active.as_mut() else { continue };
                let mut t = dt;
                if ap.overhead_left > 0.0 {
                    let burn = ap.overhead_left.min(t);
                    ap.overhead_left -= burn;
                    t -= burn;
                }
                if t > 0.0 && self.phase_rate[mi].is_finite() {
                    ap.done_bytes += self.phase_rate[mi] * t;
                }
                let finished = ap.overhead_left <= 1e-15
                    && ap.done_bytes + 1e-6 >= ap.phase.work_bytes as f64;
                if finished {
                    // Account the phase's HBM bytes exactly once, at
                    // completion: per-event truncation under-reported
                    // long multi-event phases by up to a byte per event.
                    let per_unit_total: f64 =
                        ap.phase.flows.iter().map(|f| f.per_unit).sum();
                    m.stats.hbm_bytes +=
                        (ap.phase.work_bytes as f64 * per_unit_total).round() as u64;
                    m.stats.finish_time = self.now;
                    let Some(engine) = m.engine.as_mut() else {
                        unreachable!("running engine present while active")
                    };
                    m.active = engine.next_phase(mem).map(ActivePhase::new);
                    if m.active.is_some() {
                        m.stats.phases += 1;
                    } else {
                        events.push(SimEvent::EngineDone { member: mi });
                    }
                }
            }

            // Advance transfers by dt.
            for (ti, tr) in self.transfers.iter_mut().enumerate() {
                if tr.done {
                    continue;
                }
                let mut t = dt;
                if tr.latency_left > 0.0 {
                    let burn = tr.latency_left.min(t);
                    tr.latency_left -= burn;
                    t -= burn;
                }
                if t > 0.0 && link_rate.is_finite() {
                    tr.remaining_bytes -= link_rate * t;
                } else if t > 0.0 && link_rate.is_infinite() {
                    tr.remaining_bytes = 0.0;
                }
                if tr.latency_left <= 1e-15 && tr.remaining_bytes <= 1e-6 {
                    tr.done = true;
                    events.push(SimEvent::TransferDone { transfer: ti });
                }
            }
        }
        events
    }
}

/// Run all engines to completion, sharing `mem` and the crossbar, with
/// the functional passes executed on parallel worker threads when the
/// engines' declared footprints are disjoint (see the module docs).
pub fn run(cfg: &HbmConfig, mem: &mut HbmMemory, engines: &mut [Box<dyn Engine>]) -> SimReport {
    run_mode(cfg, mem, engines, true)
}

/// [`run`] with the functional passes forced onto the calling thread —
/// the serial reference for callers driving the simulator directly. (The
/// coordinator's equivalent switch is
/// `Coordinator::set_parallel_functional(false)`, which is what
/// `hbmctl bench-host` and the determinism suite use.)
pub fn run_serial(
    cfg: &HbmConfig,
    mem: &mut HbmMemory,
    engines: &mut [Box<dyn Engine>],
) -> SimReport {
    run_mode(cfg, mem, engines, false)
}

/// Below this total declared footprint, per-dispatch thread-spawn
/// overhead outweighs the parallel win; such engine sets run serially so
/// the default mode is never slower than serial on small workloads.
/// Public so the static analyzer's parallelism pass predicts the same
/// threshold it warns about.
pub const PARALLEL_MIN_FOOTPRINT_BYTES: u64 = 1 << 20;

/// Why [`prepare_functional`] fell back to the serial path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SerialReason {
    /// The caller asked for serial execution.
    Disabled,
    /// Fewer than two engines — nothing to parallelize.
    SingleEngine,
    /// The host reports a single core (or no parallelism information).
    NoHostParallelism,
    /// Some engine declared no [`Engine::functional_ranges`], so its
    /// footprint is unknown and no disjoint view can be carved.
    UnknownRanges,
    /// Total declared footprint under [`PARALLEL_MIN_FOOTPRINT_BYTES`].
    SmallFootprint,
    /// Two engines' declared ranges share a page — the silent
    /// serialization the analyzer's `range-overlap` warning predicts.
    Overlap,
}

/// How [`prepare_functional`] executed the functional passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FunctionalMode {
    /// One worker thread per engine over disjoint [`crate::hbm::HbmView`]s.
    Parallel { workers: usize },
    Serial { reason: SerialReason },
}

impl FunctionalMode {
    pub fn is_parallel(self) -> bool {
        matches!(self, FunctionalMode::Parallel { .. })
    }
}

impl Default for FunctionalMode {
    fn default() -> Self {
        FunctionalMode::Serial { reason: SerialReason::Disabled }
    }
}

/// Serial-path debug bounds-checker: every access of an engine's
/// functional pass must stay inside the page span of its declared
/// ranges — the exact contract the parallel path's `HbmView`s enforce
/// physically. Running it on the serial path too means an engine that
/// lies about its footprint fails loudly in debug builds even when the
/// parallel path didn't engage.
struct RangeGuard<'a> {
    mem: &'a mut HbmMemory,
    /// Inclusive allowed page spans, from the declared ranges.
    pages: Vec<(u64, u64)>,
    name: String,
}

impl RangeGuard<'_> {
    fn check(&self, addr: u64, len: usize) {
        if len == 0 {
            return;
        }
        let first = addr / PAGE_BYTES;
        let last = (addr + len as u64 - 1) / PAGE_BYTES;
        for page in first..=last {
            if !self.pages.iter().any(|&(lo, hi)| (lo..=hi).contains(&page)) {
                panic!(
                    "engine {}: functional pass touched page {page} \
                     (addr {addr:#x}, {len} B) outside its declared \
                     functional ranges",
                    self.name
                );
            }
        }
    }
}

impl MemBytes for RangeGuard<'_> {
    fn read_into(&self, addr: u64, out: &mut [u8]) {
        self.check(addr, out.len());
        self.mem.read_into(addr, out);
    }

    fn write(&mut self, addr: u64, data: &[u8]) {
        self.check(addr, data.len());
        self.mem.write(addr, data);
    }
}

/// Execute every engine's functional pass up front. Parallel when
/// requested and worthwhile (≥ 2 engines, a host with > 1 core, every
/// footprint declared, all footprints page-disjoint, and enough total
/// work to amortize the worker threads); serial otherwise. Either way,
/// engines are *prepared* afterwards: `next_phase` only emits
/// precomputed phases. Returns which path ran (and if serial, why) so
/// callers — and through them the analyzer's tests — can observe
/// whether the parallel path engaged.
pub fn prepare_functional(
    mem: &mut HbmMemory,
    engines: &mut [Box<dyn Engine>],
    parallel: bool,
) -> FunctionalMode {
    let reason = 'serial: {
        if !parallel {
            break 'serial SerialReason::Disabled;
        }
        if engines.len() <= 1 {
            break 'serial SerialReason::SingleEngine;
        }
        if !std::thread::available_parallelism().map(|p| p.get() > 1).unwrap_or(false) {
            break 'serial SerialReason::NoHostParallelism;
        }
        let range_sets: Vec<Vec<(u64, u64)>> =
            engines.iter().map(|e| e.functional_ranges()).collect();
        if range_sets.iter().any(|r| r.is_empty()) {
            break 'serial SerialReason::UnknownRanges;
        }
        let footprint: u64 = range_sets
            .iter()
            .flat_map(|set| set.iter().map(|&(_, bytes)| bytes))
            .sum();
        if footprint < PARALLEL_MIN_FOOTPRINT_BYTES {
            break 'serial SerialReason::SmallFootprint;
        }
        let Some(views) = mem.take_disjoint_views(&range_sets) else {
            break 'serial SerialReason::Overlap;
        };
        let views = std::thread::scope(|scope| {
            let workers: Vec<_> = engines
                .iter_mut()
                .zip(views)
                .map(|(engine, mut view)| {
                    scope.spawn(move || {
                        engine.run_functional(&mut view);
                        view
                    })
                })
                .collect();
            workers
                .into_iter()
                .map(|w| match w.join() {
                    Ok(view) => view,
                    Err(panic) => std::panic::resume_unwind(panic),
                })
                .collect::<Vec<_>>()
        });
        mem.restore_views(views);
        return FunctionalMode::Parallel { workers: engines.len() };
    };
    for engine in engines.iter_mut() {
        let ranges = engine.functional_ranges();
        if cfg!(debug_assertions) && !ranges.is_empty() {
            let pages = ranges
                .iter()
                .filter(|&&(_, bytes)| bytes > 0)
                .map(|&(addr, bytes)| {
                    (addr / PAGE_BYTES, (addr + bytes - 1) / PAGE_BYTES)
                })
                .collect();
            let mut guard =
                RangeGuard { name: engine.name(), mem, pages };
            engine.run_functional(&mut guard);
        } else {
            engine.run_functional(mem);
        }
    }
    FunctionalMode::Serial { reason }
}

/// Placeholder engine left in a caller's slot while [`run_mode`] drives
/// the real engine inside a scoped session; swapped back before return.
struct NullEngine;

impl Engine for NullEngine {
    fn name(&self) -> String {
        "null".into()
    }
    fn next_phase(&mut self, _mem: &mut HbmMemory) -> Option<Phase> {
        None
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Run all engines to completion, with explicit control over whether the
/// functional passes use worker threads. One-shot convenience over
/// [`SimSession`]: all engines join at `t = 0` and the session drains —
/// the event sequence (and therefore every timing) is identical to the
/// historical round-scoped loop.
pub fn run_mode(
    cfg: &HbmConfig,
    mem: &mut HbmMemory,
    engines: &mut [Box<dyn Engine>],
    parallel: bool,
) -> SimReport {
    let functional = prepare_functional(mem, engines, parallel);
    let mut session = SimSession::new(cfg.clone());
    let ids: Vec<usize> = engines
        .iter_mut()
        .map(|slot| {
            let engine = std::mem::replace(slot, Box::new(NullEngine) as Box<dyn Engine>);
            session.add_engine(engine, mem).0
        })
        .collect();
    while !session.idle() {
        session.advance(mem);
    }
    let makespan = session.now();
    let mut stats = Vec::with_capacity(ids.len());
    for (slot, &id) in engines.iter_mut().zip(&ids) {
        let (engine, s) = session.take_engine(id);
        *slot = engine;
        stats.push(s);
    }
    SimReport { makespan, engines: stats, functional }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::hbm::config::FabricClock;
    use crate::util::units::MIB;

    /// Test engine: streams `total` bytes from a fixed range in one phase.
    struct Streamer {
        addr: u64,
        total: u64,
        cap: f64,
        emitted: bool,
    }

    impl Engine for Streamer {
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }

        fn name(&self) -> String {
            format!("streamer@{:#x}", self.addr)
        }
        fn next_phase(&mut self, _mem: &mut HbmMemory) -> Option<Phase> {
            if self.emitted {
                return None;
            }
            self.emitted = true;
            Some(
                Phase::new("stream", self.total)
                    .with_flow(Flow::new(0, self.addr, 256 * MIB), 1.0)
                    .with_rate_cap(self.cap),
            )
        }
    }

    fn streamer(addr: u64, total: u64, cap: f64) -> Box<dyn Engine> {
        Box::new(Streamer { addr, total, cap, emitted: false })
    }

    #[test]
    fn single_engine_runs_at_port_rate() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 512 * MIB;
        let mut engines = vec![streamer(0, total, f64::INFINITY)];
        let r = run(&cfg, &mut mem, &mut engines);
        let expect = total as f64 / cfg.port_effective();
        assert!((r.makespan / expect - 1.0).abs() < 1e-6);
        assert_eq!(r.engines[0].hbm_bytes, total);
    }

    #[test]
    fn separated_engines_overlap_perfectly() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 256 * MIB;
        let mut engines: Vec<Box<dyn Engine>> = (0..4)
            .map(|i| streamer(i * 256 * MIB, total, f64::INFINITY))
            .collect();
        let r = run(&cfg, &mut mem, &mut engines);
        let expect = total as f64 / cfg.port_effective();
        assert!((r.makespan / expect - 1.0).abs() < 1e-6, "no slowdown expected");
    }

    #[test]
    fn contending_engines_halve() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 256 * MIB;
        let mut engines: Vec<Box<dyn Engine>> =
            (0..2).map(|_| streamer(0, total, f64::INFINITY)).collect();
        let r = run(&cfg, &mut mem, &mut engines);
        let expect = 2.0 * total as f64 / cfg.segment_capacity();
        assert!((r.makespan / expect - 1.0).abs() < 1e-6);
    }

    #[test]
    fn compute_cap_binds() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 100 * MIB;
        let cap = 1e9;
        let mut engines = vec![streamer(0, total, cap)];
        let r = run(&cfg, &mut mem, &mut engines);
        assert!((r.makespan - total as f64 / cap).abs() / r.makespan < 1e-6);
    }

    #[test]
    fn capped_engine_releases_bandwidth() {
        // One capped + one uncapped engine on the same segment: the
        // uncapped one should get segment_capacity - cap.
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 256 * MIB;
        let cap = 1e9;
        let mut engines =
            vec![streamer(0, total, cap), streamer(0, total, f64::INFINITY)];
        let r = run(&cfg, &mut mem, &mut engines);
        // Fast engine rate = seg - 1 GB/s; finishes first. Then slow one
        // continues at its cap.
        let fast_rate = cfg.segment_capacity() - cap;
        let t_fast = total as f64 / fast_rate;
        assert!(
            (r.engines[1].finish_time / t_fast - 1.0).abs() < 1e-3,
            "fast={} expect={}",
            r.engines[1].finish_time,
            t_fast
        );
        let t_slow = total as f64 / cap;
        assert!((r.engines[0].finish_time / t_slow - 1.0).abs() < 1e-3);
    }

    #[test]
    fn multi_phase_engine_completes_all_phases() {
        struct TwoPhase {
            left: u32,
        }
        impl Engine for TwoPhase {
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }

            fn name(&self) -> String {
                "twophase".into()
            }
            fn next_phase(&mut self, _m: &mut HbmMemory) -> Option<Phase> {
                if self.left == 0 {
                    return None;
                }
                self.left -= 1;
                Some(
                    Phase::new("p", MIB)
                        .with_flow(Flow::new(0, 0, MIB), 1.0),
                )
            }
        }
        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut engines: Vec<Box<dyn Engine>> = vec![Box::new(TwoPhase { left: 3 })];
        let r = run(&cfg, &mut mem, &mut engines);
        assert_eq!(r.engines[0].phases, 3);
        assert_eq!(r.engines[0].hbm_bytes, 3 * MIB);
    }

    #[test]
    fn overhead_only_phase_advances_time() {
        struct Sleeper(bool);
        impl Engine for Sleeper {
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }

            fn name(&self) -> String {
                "sleeper".into()
            }
            fn next_phase(&mut self, _m: &mut HbmMemory) -> Option<Phase> {
                if self.0 {
                    return None;
                }
                self.0 = true;
                Some(Phase::compute("sleep", 1e-3))
            }
        }
        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut engines: Vec<Box<dyn Engine>> = vec![Box::new(Sleeper(false))];
        let r = run(&cfg, &mut mem, &mut engines);
        assert!((r.makespan - 1e-3).abs() < 1e-9);
    }

    // -----------------------------------------------------------------
    // Session semantics: mid-flight joins, link transfers, accounting.
    // -----------------------------------------------------------------

    /// An engine whose single phase carries an extra fractional egress
    /// flow: `per_unit_total` = 1.0 + ratio, the shape whose per-event
    /// truncation used to leak bytes.
    struct RatioStreamer {
        addr: u64,
        total: u64,
        ratio: f64,
        emitted: bool,
    }

    impl Engine for RatioStreamer {
        fn as_any(&self) -> &dyn std::any::Any {
            self
        }
        fn name(&self) -> String {
            "ratio".into()
        }
        fn next_phase(&mut self, _mem: &mut HbmMemory) -> Option<Phase> {
            if self.emitted {
                return None;
            }
            self.emitted = true;
            Some(
                Phase::new("scan", self.total)
                    .with_flow(Flow::new(0, self.addr, 256 * MIB), 1.0)
                    .with_flow(Flow::new(1, self.addr, 64 * MIB), self.ratio),
            )
        }
    }

    #[test]
    fn hbm_bytes_are_exact_across_many_events() {
        // One long fractional-egress phase sliced by 40 short co-runner
        // phases on the same segment: 40+ events inside the long phase.
        // The moved-bytes total must still be *exact* — the old per-event
        // `(adv * per_unit) as u64` truncation lost up to a byte per
        // event.
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 64 * MIB + 7; // odd size: fractional per-event slices
        let ratio = 0.3303;
        let mut engines: Vec<Box<dyn Engine>> = vec![Box::new(RatioStreamer {
            addr: 0,
            total,
            ratio,
            emitted: false,
        })];
        // left = 1..=40: the fleet thins out over 40 staggered waves, so
        // the long phase advances in 40+ unequal slices.
        for i in 0..40u32 {
            engines.push(Box::new(TickEngine { left: i + 1 }));
        }
        struct TickEngine {
            left: u32,
        }
        impl Engine for TickEngine {
            fn as_any(&self) -> &dyn std::any::Any {
                self
            }
            fn name(&self) -> String {
                "tick".into()
            }
            fn next_phase(&mut self, _m: &mut HbmMemory) -> Option<Phase> {
                if self.left == 0 {
                    return None;
                }
                self.left -= 1;
                Some(Phase::new("tick", MIB).with_flow(Flow::new(0, 0, MIB), 1.0))
            }
        }
        let r = run(&cfg, &mut mem, &mut engines);
        let want = (total as f64 * (1.0 + ratio)).round() as u64;
        assert_eq!(
            r.engines[0].hbm_bytes, want,
            "phase totals must be rounded once, not truncated per event"
        );
        for (i, tick) in r.engines[1..].iter().enumerate() {
            assert_eq!(tick.hbm_bytes, (i as u64 + 1) * MIB, "tick engine {i}");
        }
    }

    #[test]
    fn late_joining_engine_overlaps_and_finishes_later() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let total = 256 * MIB;
        let mut session = SimSession::new(cfg.clone());
        // First engine runs alone on its own segment...
        let (a, _) = session.add_engine(streamer(0, total, f64::INFINITY), &mut mem);
        let solo = total as f64 / cfg.port_effective();
        let events = session.advance(&mut mem);
        assert_eq!(events, vec![SimEvent::EngineDone { member: a }]);
        assert!((session.now() / solo - 1.0).abs() < 1e-9);
        // ...a second joins *after* the first finished, on a separate
        // segment: it must take exactly the solo time again, finishing at
        // 2× solo on the session clock.
        let (b, _) =
            session.add_engine(streamer(256 * MIB, total, f64::INFINITY), &mut mem);
        let events = session.advance(&mut mem);
        assert_eq!(events, vec![SimEvent::EngineDone { member: b }]);
        assert!((session.now() / (2.0 * solo) - 1.0).abs() < 1e-9);
        assert!(session.idle());
        let (_, stats_b) = session.take_engine(b);
        assert!((stats_b.finish_time / (2.0 * solo) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transfers_share_the_link_and_overlap_compute() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let mut session = SimSession::new(cfg.clone());
        let bw = 10e9;
        session.set_link_bandwidth(bw);
        // Two equal transfers: each sees bw/2 for its whole life, so both
        // complete together at 2×(bytes/bw).
        let bytes = 1u64 << 30;
        let t1 = session.add_transfer(bytes, 0.0);
        let t2 = session.add_transfer(bytes, 0.0);
        // A compute engine slow enough (1 GB/s cap) to outlast the
        // transfer window, overlapping it completely.
        let (e, _) = session.add_engine(streamer(0, 512 * MIB, 1e9), &mut mem);
        let events = session.advance(&mut mem);
        assert!(events.contains(&SimEvent::TransferDone { transfer: t1 }));
        assert!(events.contains(&SimEvent::TransferDone { transfer: t2 }));
        let expect = 2.0 * bytes as f64 / bw;
        assert!(
            (session.now() / expect - 1.0).abs() < 1e-9,
            "shared link must halve each transfer: {} vs {expect}",
            session.now()
        );
        // The engine kept running under the transfers: full overlap.
        assert!(session.overlap_seconds() > 0.0);
        assert!(
            (session.overlap_seconds() / session.link_busy_seconds() - 1.0).abs()
                < 1e-9,
            "compute covered the whole transfer window"
        );
        let events = session.advance(&mut mem);
        assert_eq!(events, vec![SimEvent::EngineDone { member: e }]);
        assert!(session.idle());
    }

    #[test]
    fn transfer_latency_burns_before_bytes() {
        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut session = SimSession::new(cfg);
        session.set_link_bandwidth(1e9);
        let t = session.add_transfer(1_000_000, 2e-6);
        let events = session.advance(&mut mem);
        assert_eq!(events, vec![SimEvent::TransferDone { transfer: t }]);
        let expect = 2e-6 + 1e-3;
        assert!((session.now() - expect).abs() < 1e-12);
        // Zero-byte transfers still cost the latency.
        let t2 = session.add_transfer(0, 2e-6);
        let events = session.advance(&mut mem);
        assert_eq!(events, vec![SimEvent::TransferDone { transfer: t2 }]);
        assert!((session.now() - (expect + 2e-6)).abs() < 1e-12);
    }

    #[test]
    fn aborted_engine_frees_its_slot_and_keeps_partial_bytes() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let mut session = SimSession::new(cfg.clone());
        let total = 256 * MIB;
        // Two engines on separate segments; the second finishes first
        // because it is half the size.
        let (a, _) = session.add_engine(streamer(0, total, f64::INFINITY), &mut mem);
        let (b, _) = session.add_engine(streamer(256 * MIB, total / 2, f64::INFINITY), &mut mem);
        let events = session.advance(&mut mem);
        assert_eq!(events, vec![SimEvent::EngineDone { member: b }]);
        // Abort the still-running engine mid-phase: the session must go
        // idle (no dangling phase) and the partial bytes must be about
        // half the footprint (b finished at total/2 port-rate seconds).
        let stats = session.abort_engine(a);
        session.take_engine(b);
        assert!(session.idle(), "aborted phase must not stay active");
        let half = (total / 2) as f64;
        assert!(
            (stats.hbm_bytes as f64 - half).abs() / half < 1e-6,
            "partial HBM bytes pro-rated: got {}",
            stats.hbm_bytes
        );
        // The freed slot is recycled by the next join.
        let (c, _) = session.add_engine(streamer(0, MIB, f64::INFINITY), &mut mem);
        assert!(c == a || c == b, "aborted member slot must be reusable");
        let events = session.advance(&mut mem);
        assert_eq!(events, vec![SimEvent::EngineDone { member: c }]);
    }

    #[test]
    fn aborted_transfer_never_completes_and_frees_the_link() {
        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut session = SimSession::new(cfg);
        let bw = 1e9;
        session.set_link_bandwidth(bw);
        let bytes = 1u64 << 30;
        let doomed = session.add_transfer(bytes, 0.0);
        let survivor = session.add_transfer(bytes, 0.0);
        session.abort_transfer(doomed);
        // Only the survivor remains: it gets the whole link to itself.
        let events = session.advance(&mut mem);
        assert_eq!(events, vec![SimEvent::TransferDone { transfer: survivor }]);
        let expect = bytes as f64 / bw;
        assert!(
            (session.now() / expect - 1.0).abs() < 1e-9,
            "aborted transfer must stop sharing the link"
        );
        assert!(session.idle());
    }

    #[test]
    fn session_matches_one_shot_run_exactly() {
        // Driving the same engine set through a session by hand must
        // reproduce run()'s makespan bit-for-bit (same event sequence).
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let total = 192 * MIB;
        let build = |n: usize| -> Vec<Box<dyn Engine>> {
            (0..n).map(|i| streamer(i as u64 * 128 * MIB, total, f64::INFINITY)).collect()
        };
        let mut mem = HbmMemory::new();
        let report = run_serial(&cfg, &mut mem, &mut build(3));
        let mut mem2 = HbmMemory::new();
        let mut session = SimSession::new(cfg);
        let mut engines = build(3);
        for engine in engines.drain(..) {
            session.add_engine(engine, &mut mem2);
        }
        while !session.idle() {
            session.advance(&mut mem2);
        }
        assert_eq!(session.now().to_bits(), report.makespan.to_bits());
    }
}
