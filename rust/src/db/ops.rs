//! Relational operators over columns, MonetDB-style: operator-at-a-time,
//! late materialization via candidate (OID) lists.

use super::column::ColumnData;
use crate::cpu;

/// Range selection: candidate list of positions where `lo ≤ v ≤ hi`.
pub fn range_select(col: &ColumnData, lo: u32, hi: u32, threads: usize) -> Vec<u32> {
    let Some(data) = col.as_u32() else {
        panic!("range_select needs a u32 column")
    };
    cpu::selection::range_select(data, lo, hi, threads)
}

/// Hash join on two u32 key columns: (left-pos, right-pos) pairs.
/// `left` is the build (small) side — Algorithm 2's S.
pub fn hash_join(
    left: &ColumnData,
    right: &ColumnData,
    threads: usize,
) -> Vec<(u32, u32)> {
    let (Some(s), Some(l)) = (left.as_u32(), right.as_u32()) else {
        panic!("hash_join needs u32 build and probe columns")
    };
    cpu::join::hash_join_positions(s, l, threads)
}

/// Positional projection (gather).
pub fn project(col: &ColumnData, positions: &[u32]) -> ColumnData {
    col.gather(positions)
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AggKind {
    Count,
    SumF32,
    SumU32,
    MinU32,
    MaxU32,
}

impl AggKind {
    /// The element type this aggregate consumes, or `None` for any
    /// column (`Count`) — the single table both the CPU executor and the
    /// pipeline lowering validate against, so their error payloads can
    /// never drift apart.
    pub fn expected_input(&self) -> Option<&'static str> {
        match self {
            AggKind::Count => None,
            AggKind::SumF32 => Some("f32 column"),
            AggKind::SumU32 | AggKind::MinU32 | AggKind::MaxU32 => {
                Some("u32 column")
            }
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum AggResult {
    Count(u64),
    F64(f64),
    U64(u64),
}

/// Scalar aggregate over a column.
pub fn aggregate(col: &ColumnData, kind: AggKind) -> AggResult {
    fn need_u32<'a>(col: &'a ColumnData, what: &str) -> &'a [u32] {
        match col.as_u32() {
            Some(v) => v,
            None => panic!("{what} needs u32"),
        }
    }
    match kind {
        AggKind::Count => AggResult::Count(col.len() as u64),
        AggKind::SumF32 => {
            let Some(v) = col.as_f32() else { panic!("SumF32 needs f32") };
            AggResult::F64(v.iter().map(|&x| x as f64).sum())
        }
        AggKind::SumU32 => {
            let v = need_u32(col, "SumU32");
            AggResult::U64(v.iter().map(|&x| x as u64).sum())
        }
        AggKind::MinU32 => {
            let v = need_u32(col, "MinU32");
            AggResult::U64(v.iter().copied().min().unwrap_or(0) as u64)
        }
        AggKind::MaxU32 => {
            let v = need_u32(col, "MaxU32");
            AggResult::U64(v.iter().copied().max().unwrap_or(0) as u64)
        }
    }
}

/// Group-by-key sum (u32 keys, f32 values): the reduction-heavy OLAP
/// pattern the paper's §II motivates. Returns sorted (key, sum, count).
pub fn group_sum(
    keys: &ColumnData,
    values: &ColumnData,
) -> Vec<(u32, f64, u64)> {
    let Some(k) = keys.as_u32() else { panic!("group keys must be u32") };
    let Some(v) = values.as_f32() else { panic!("group values must be f32") };
    assert_eq!(k.len(), v.len());
    let mut map: std::collections::BTreeMap<u32, (f64, u64)> =
        std::collections::BTreeMap::new();
    for (&key, &val) in k.iter().zip(v) {
        let e = map.entry(key).or_insert((0.0, 0));
        e.0 += val as f64;
        e.1 += 1;
    }
    map.into_iter().map(|(key, (s, c))| (key, s, c)).collect()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn select_then_project() {
        let col = ColumnData::U32(vec![5, 50, 500, 55].into());
        let cand = range_select(&col, 50, 100, 2);
        assert_eq!(cand, vec![1, 3]);
        let vals = project(&col, &cand);
        assert_eq!(vals, ColumnData::U32(vec![50, 55].into()));
    }

    #[test]
    fn join_returns_positions_both_sides() {
        let build = ColumnData::U32(vec![10, 20, 10].into());
        let probe = ColumnData::U32(vec![20, 10, 99].into());
        let mut pairs = hash_join(&build, &probe, 1);
        pairs.sort_unstable();
        // probe[0]=20 matches build pos 1; probe[1]=10 matches build pos 0
        // and 2 (duplicate build keys).
        assert_eq!(pairs, vec![(0, 1), (1, 0), (2, 1)]);
    }

    #[test]
    fn aggregates() {
        let u = ColumnData::U32(vec![3, 1, 2].into());
        assert_eq!(aggregate(&u, AggKind::Count), AggResult::Count(3));
        assert_eq!(aggregate(&u, AggKind::SumU32), AggResult::U64(6));
        assert_eq!(aggregate(&u, AggKind::MinU32), AggResult::U64(1));
        assert_eq!(aggregate(&u, AggKind::MaxU32), AggResult::U64(3));
        let f = ColumnData::F32(vec![1.5, 2.5].into());
        assert_eq!(aggregate(&f, AggKind::SumF32), AggResult::F64(4.0));
    }

    #[test]
    fn group_sum_groups() {
        let k = ColumnData::U32(vec![1, 2, 1, 2, 3].into());
        let v = ColumnData::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0].into());
        let g = group_sum(&k, &v);
        assert_eq!(g, vec![(1, 4.0, 2), (2, 6.0, 2), (3, 5.0, 1)]);
    }
}
