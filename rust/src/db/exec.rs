//! Operator-at-a-time plan executor with a small builder API.
//!
//! MonetDB executes MAL plans one operator at a time, fully materializing
//! each intermediate (the paper's §II notes column stores "materialize
//! their intermediate results heavily" — a key reason memory bandwidth
//! matters). The executor mirrors that: every step produces a concrete
//! intermediate (candidate list, pair list, or column) and optionally
//! dispatches to the FPGA accelerator hook instead of the CPU operator.

use super::column::{Catalog, ColumnData};
use super::ops::{self, AggKind, AggResult};
use super::request::OffloadRequest;
use super::udf::FpgaAccelerator;
use crate::coordinator::ColumnKey;

/// Logical plan nodes (tree; children boxed).
#[derive(Debug, Clone)]
pub enum Plan {
    /// Produce a column from the catalog.
    ScanColumn { table: String, column: String },
    /// Candidate list of positions in `input`'s column matching the range.
    Select { input: Box<Plan>, lo: u32, hi: u32 },
    /// Gather `input` column at positions produced by `candidates`.
    Project { input: Box<Plan>, candidates: Box<Plan> },
    /// Join build-side column (left) with probe-side column (right);
    /// yields (left-pos, right-pos) pairs.
    Join { left: Box<Plan>, right: Box<Plan> },
    /// Take left or right positions of a Join result as a candidate list.
    JoinSide { join: Box<Plan>, left_side: bool },
    /// Scalar aggregate over a column.
    Aggregate { input: Box<Plan>, kind: AggKind },
}

impl Plan {
    pub fn scan(table: &str, column: &str) -> Plan {
        Plan::ScanColumn { table: table.into(), column: column.into() }
    }

    pub fn select(self, lo: u32, hi: u32) -> Plan {
        Plan::Select { input: Box::new(self), lo, hi }
    }

    pub fn project(self, candidates: Plan) -> Plan {
        Plan::Project { input: Box::new(self), candidates: Box::new(candidates) }
    }

    pub fn join(self, probe: Plan) -> Plan {
        Plan::Join { left: Box::new(self), right: Box::new(probe) }
    }

    pub fn join_side(self, left_side: bool) -> Plan {
        Plan::JoinSide { join: Box::new(self), left_side }
    }

    pub fn aggregate(self, kind: AggKind) -> Plan {
        Plan::Aggregate { input: Box::new(self), kind }
    }
}

/// A materialized intermediate.
#[derive(Debug, Clone, PartialEq)]
pub enum Intermediate {
    Column(ColumnData),
    Candidates(Vec<u32>),
    Pairs(Vec<(u32, u32)>),
    Scalar(AggResult),
}

impl Intermediate {
    pub fn expect_column(self) -> ColumnData {
        match self {
            Intermediate::Column(c) => c,
            other => panic!("expected column, got {other:?}"),
        }
    }

    pub fn expect_candidates(self) -> Vec<u32> {
        match self {
            Intermediate::Candidates(c) => c,
            other => panic!("expected candidates, got {other:?}"),
        }
    }

    pub fn expect_pairs(self) -> Vec<(u32, u32)> {
        match self {
            Intermediate::Pairs(p) => p,
            other => panic!("expected pairs, got {other:?}"),
        }
    }

    pub fn expect_scalar(self) -> AggResult {
        match self {
            Intermediate::Scalar(s) => s,
            other => panic!("expected scalar, got {other:?}"),
        }
    }
}

/// The cache identity of a plan node, when it is a direct base-column
/// scan: intermediates have no stable identity and are never cached.
fn scan_key(plan: &Plan) -> Option<ColumnKey> {
    match plan {
        Plan::ScanColumn { table, column } => {
            Some(ColumnKey::new(table.clone(), column.clone()))
        }
        _ => None,
    }
}

/// Executor: CPU operators by default; select/join optionally offloaded to
/// the FPGA accelerator (the UDF path of doppioDB-style integration).
pub struct Executor<'a> {
    pub catalog: &'a Catalog,
    pub threads: usize,
    pub accelerator: Option<&'a mut FpgaAccelerator>,
}

impl<'a> Executor<'a> {
    pub fn cpu(catalog: &'a Catalog, threads: usize) -> Self {
        Self { catalog, threads, accelerator: None }
    }

    pub fn accelerated(
        catalog: &'a Catalog,
        threads: usize,
        accelerator: &'a mut FpgaAccelerator,
    ) -> Self {
        Self { catalog, threads, accelerator: Some(accelerator) }
    }

    pub fn run(&mut self, plan: &Plan) -> Intermediate {
        match plan {
            Plan::ScanColumn { table, column } => {
                let t = self
                    .catalog
                    .table(table)
                    .unwrap_or_else(|| panic!("unknown table '{table}'"));
                let c = t
                    .column(column)
                    .unwrap_or_else(|| panic!("unknown column '{table}.{column}'"));
                Intermediate::Column(c.data.clone())
            }
            Plan::Select { input, lo, hi } => {
                let key = scan_key(input);
                let col = self.run(input).expect_column();
                let cands = match self.accelerator.as_mut() {
                    Some(acc) => {
                        let req = OffloadRequest::select(*lo, *hi)
                            .on(col.as_u32().expect("u32"))
                            .keyed(key);
                        acc.submit(req).wait_selection().0
                    }
                    None => ops::range_select(&col, *lo, *hi, self.threads),
                };
                Intermediate::Candidates(cands)
            }
            Plan::Project { input, candidates } => {
                let col = self.run(input).expect_column();
                let cands = self.run(candidates).expect_candidates();
                Intermediate::Column(ops::project(&col, &cands))
            }
            Plan::Join { left, right } => {
                let (s_key, l_key) = (scan_key(left), scan_key(right));
                let build = self.run(left).expect_column();
                let probe = self.run(right).expect_column();
                let pairs = match self.accelerator.as_mut() {
                    Some(acc) => {
                        let req = OffloadRequest::join(
                            build.as_u32().expect("u32"),
                            probe.as_u32().expect("u32"),
                        )
                        .keyed(s_key)
                        .probe_keyed(l_key);
                        acc.submit(req).wait_join().0
                    }
                    None => ops::hash_join(&build, &probe, self.threads),
                };
                Intermediate::Pairs(pairs)
            }
            Plan::JoinSide { join, left_side } => {
                let pairs = self.run(join).expect_pairs();
                Intermediate::Candidates(
                    pairs
                        .iter()
                        .map(|&(l, r)| if *left_side { l } else { r })
                        .collect(),
                )
            }
            Plan::Aggregate { input, kind } => {
                let col = self.run(input).expect_column();
                Intermediate::Scalar(ops::aggregate(&col, *kind))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::column::{Column, Table};

    fn catalog() -> Catalog {
        let mut cat = Catalog::new();
        cat.register(Table::new(
            "orders",
            vec![
                Column::u32("okey", vec![1, 2, 3, 4, 5]),
                Column::u32("cust", vec![10, 20, 10, 30, 20]),
                Column::f32("total", vec![5.0, 15.0, 25.0, 35.0, 45.0]),
            ],
        ));
        cat.register(Table::new(
            "customers",
            vec![Column::u32("ckey", vec![10, 20, 30])],
        ));
        cat
    }

    #[test]
    fn select_project_aggregate_pipeline() {
        let cat = catalog();
        let mut ex = Executor::cpu(&cat, 2);
        // SELECT sum(total) FROM orders WHERE okey BETWEEN 2 AND 4
        let plan = Plan::scan("orders", "total").project(
            Plan::scan("orders", "okey").select(2, 4),
        );
        let col = ex.run(&plan).expect_column();
        assert_eq!(col, ColumnData::F32(vec![15.0, 25.0, 35.0]));
        let agg = ex
            .run(&plan.clone().aggregate(AggKind::SumF32))
            .expect_scalar();
        assert_eq!(agg, AggResult::F64(75.0));
    }

    #[test]
    fn join_and_sides() {
        let cat = catalog();
        let mut ex = Executor::cpu(&cat, 1);
        // customers ⋈ orders ON ckey = cust
        let join =
            Plan::scan("customers", "ckey").join(Plan::scan("orders", "cust"));
        let pairs = ex.run(&join).expect_pairs();
        assert_eq!(pairs.len(), 5, "every order has a customer");
        // Project order totals of customer 20's orders.
        let plan = Plan::scan("orders", "total")
            .project(join.join_side(false));
        let col = ex.run(&plan).expect_column();
        assert_eq!(col.len(), 5);
    }

    #[test]
    fn accelerated_executor_reuses_resident_columns() {
        let cat = catalog();
        let mut acc = FpgaAccelerator::new(crate::hbm::HbmConfig::default());
        // Same scan twice on one accelerator: the second offload must hit
        // the coordinator's column cache via the (table, column) key.
        let plan = Plan::scan("orders", "total")
            .project(Plan::scan("orders", "okey").select(2, 4));
        let a = Executor::accelerated(&cat, 2, &mut acc).run(&plan);
        let b = Executor::accelerated(&cat, 2, &mut acc).run(&plan);
        assert_eq!(a, b);
        let stats = acc.stats();
        assert_eq!(stats.completed(), 2);
        assert_eq!(stats.cache.hits, 1, "repeat scan must be HBM-resident");
    }

    #[test]
    #[should_panic]
    fn unknown_table_panics() {
        let cat = catalog();
        let mut ex = Executor::cpu(&cat, 1);
        ex.run(&Plan::scan("nope", "x"));
    }
}
