//! Plan executor: CPU operators, with accelerated plans routed through
//! the card's pipeline API.
//!
//! MonetDB executes MAL plans one operator at a time, fully materializing
//! each intermediate (the paper's §II notes column stores "materialize
//! their intermediate results heavily" — a key reason memory bandwidth
//! matters). The CPU path of [`Executor::run`] mirrors that: every step
//! produces a concrete intermediate (candidate list, pair list, or
//! column).
//!
//! With an accelerator attached, `run` no longer walks the tree one
//! blocking offload at a time: it lowers the whole plan into a
//! [`PipelineRequest`](super::pipeline::PipelineRequest) and submits it
//! through `FpgaAccelerator::submit_plan`, so dependent operators consume
//! their parents' outputs directly from HBM instead of round-tripping
//! through the host. The historical operator-at-a-time offload walk is
//! kept behind [`Executor::operator_at_a_time`] — figure drivers use it
//! to measure exactly the data movement the pipeline deletes.
//!
//! Errors (unknown tables/columns, producer/consumer type mismatches) are
//! typed as [`ExecError`] on the library path; panicking conveniences
//! (`Intermediate::expect_*`) remain for examples, benches and tests.

use std::sync::Arc;

use super::column::{Catalog, ColumnData};
use super::ops::{self, AggKind, AggResult};
use super::pipeline::{PipelineError, PipelineRequest};
use super::request::OffloadRequest;
use super::udf::FpgaAccelerator;
use crate::coordinator::{ColumnKey, JobOutput};

/// Logical plan nodes (tree; children boxed).
#[derive(Debug, Clone)]
pub enum Plan {
    /// Produce a column from the catalog.
    ScanColumn { table: String, column: String },
    /// Candidate list of positions in `input`'s column matching the range.
    Select { input: Box<Plan>, lo: u32, hi: u32 },
    /// Gather `input` column at positions produced by `candidates`.
    Project { input: Box<Plan>, candidates: Box<Plan> },
    /// Join build-side column (left) with probe-side column (right);
    /// yields (left-pos, right-pos) pairs.
    Join { left: Box<Plan>, right: Box<Plan> },
    /// Take left or right positions of a Join result as a candidate list.
    JoinSide { join: Box<Plan>, left_side: bool },
    /// Scalar aggregate over a column.
    Aggregate { input: Box<Plan>, kind: AggKind },
}

impl Plan {
    pub fn scan(table: &str, column: &str) -> Plan {
        Plan::ScanColumn { table: table.into(), column: column.into() }
    }

    pub fn select(self, lo: u32, hi: u32) -> Plan {
        Plan::Select { input: Box::new(self), lo, hi }
    }

    pub fn project(self, candidates: Plan) -> Plan {
        Plan::Project { input: Box::new(self), candidates: Box::new(candidates) }
    }

    pub fn join(self, probe: Plan) -> Plan {
        Plan::Join { left: Box::new(self), right: Box::new(probe) }
    }

    pub fn join_side(self, left_side: bool) -> Plan {
        Plan::JoinSide { join: Box::new(self), left_side }
    }

    pub fn aggregate(self, kind: AggKind) -> Plan {
        Plan::Aggregate { input: Box::new(self), kind }
    }
}

/// Why a plan failed to execute.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExecError {
    /// A scan names a table the catalog does not have.
    UnknownTable(String),
    /// A scan names a column its table does not have.
    UnknownColumn { table: String, column: String },
    /// An operator was fed the wrong kind of intermediate.
    Type {
        context: &'static str,
        expected: &'static str,
        got: &'static str,
    },
    /// The pipeline lowering rejected the plan (accelerated path only;
    /// name/type errors are mapped onto the variants above).
    Pipeline(PipelineError),
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::UnknownTable(t) => write!(f, "unknown table '{t}'"),
            ExecError::UnknownColumn { table, column } => {
                write!(f, "unknown column '{table}.{column}'")
            }
            ExecError::Type { context, expected, got } => {
                write!(f, "{context}: expected {expected}, got {got}")
            }
            ExecError::Pipeline(e) => write!(f, "pipeline: {e}"),
        }
    }
}

impl std::error::Error for ExecError {}

impl From<PipelineError> for ExecError {
    fn from(e: PipelineError) -> Self {
        match e {
            PipelineError::UnknownTable(t) => ExecError::UnknownTable(t),
            PipelineError::UnknownColumn { table, column } => {
                ExecError::UnknownColumn { table, column }
            }
            PipelineError::TypeMismatch { context, expected, got } => {
                ExecError::Type { context, expected, got }
            }
            other => ExecError::Pipeline(other),
        }
    }
}

/// A materialized intermediate. Like [`ColumnData`], the vector-shaped
/// variants are shared `Arc` slices: cloning an intermediate (or taking
/// one out of a pipeline handle) never copies the payload bytes.
#[derive(Debug, Clone, PartialEq)]
pub enum Intermediate {
    Column(ColumnData),
    Candidates(Arc<[u32]>),
    Pairs(Arc<[(u32, u32)]>),
    Scalar(AggResult),
}

impl Intermediate {
    /// The intermediate's kind, for error messages (same vocabulary the
    /// pipeline lowering uses, so errors compare equal across paths).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Intermediate::Column(_) => "column",
            Intermediate::Candidates(_) => "candidate list",
            Intermediate::Pairs(_) => "join pairs",
            Intermediate::Scalar(_) => "scalar",
        }
    }

    /// Panicking convenience for examples/benches; the library path uses
    /// the typed [`into_column`](Intermediate::into_column).
    pub fn expect_column(self) -> ColumnData {
        match self {
            Intermediate::Column(c) => c,
            other => panic!("expected column, got {other:?}"),
        }
    }

    pub fn expect_candidates(self) -> Arc<[u32]> {
        match self {
            Intermediate::Candidates(c) => c,
            other => panic!("expected candidates, got {other:?}"),
        }
    }

    pub fn expect_pairs(self) -> Arc<[(u32, u32)]> {
        match self {
            Intermediate::Pairs(p) => p,
            other => panic!("expected pairs, got {other:?}"),
        }
    }

    pub fn expect_scalar(self) -> AggResult {
        match self {
            Intermediate::Scalar(s) => s,
            other => panic!("expected scalar, got {other:?}"),
        }
    }

    /// Typed accessor: the column, or an [`ExecError::Type`] naming the
    /// consuming operator.
    pub fn into_column(self, context: &'static str) -> Result<ColumnData, ExecError> {
        match self {
            Intermediate::Column(c) => Ok(c),
            other => Err(ExecError::Type {
                context,
                expected: "column",
                got: other.kind_name(),
            }),
        }
    }

    /// Typed accessor: the candidate list, or an [`ExecError::Type`].
    pub fn into_candidates(
        self,
        context: &'static str,
    ) -> Result<Arc<[u32]>, ExecError> {
        match self {
            Intermediate::Candidates(c) => Ok(c),
            other => Err(ExecError::Type {
                context,
                expected: "candidate list",
                got: other.kind_name(),
            }),
        }
    }

    /// Typed accessor: the pair list, or an [`ExecError::Type`].
    pub fn into_pairs(
        self,
        context: &'static str,
    ) -> Result<Arc<[(u32, u32)]>, ExecError> {
        match self {
            Intermediate::Pairs(p) => Ok(p),
            other => Err(ExecError::Type {
                context,
                expected: "join pairs",
                got: other.kind_name(),
            }),
        }
    }
}

/// The cache identity of a plan node, when it is a direct base-column
/// scan: intermediates have no stable identity and are never cached.
fn scan_key(plan: &Plan) -> Option<ColumnKey> {
    match plan {
        Plan::ScanColumn { table, column } => {
            Some(ColumnKey::new(table.clone(), column.clone()))
        }
        _ => None,
    }
}

/// Executor: CPU operators by default. With an accelerator attached,
/// plans are lowered whole and submitted through the pipeline API
/// (dependent operators keep their intermediates in HBM); the historical
/// blocking per-operator offload walk remains available via
/// [`operator_at_a_time`](Executor::operator_at_a_time).
pub struct Executor<'a> {
    pub catalog: &'a Catalog,
    pub threads: usize,
    pub accelerator: Option<&'a mut FpgaAccelerator>,
    /// Accelerated plans go through `submit_plan` (the default) instead
    /// of one blocking offload per operator.
    pipelined: bool,
}

impl<'a> Executor<'a> {
    pub fn cpu(catalog: &'a Catalog, threads: usize) -> Self {
        Self { catalog, threads, accelerator: None, pipelined: true }
    }

    pub fn accelerated(
        catalog: &'a Catalog,
        threads: usize,
        accelerator: &'a mut FpgaAccelerator,
    ) -> Self {
        Self { catalog, threads, accelerator: Some(accelerator), pipelined: true }
    }

    /// Use the historical operator-at-a-time offload walk: one blocking
    /// submission per select/join, every intermediate round-tripping
    /// through the host. Kept for measuring what the pipeline saves.
    pub fn operator_at_a_time(mut self) -> Self {
        self.pipelined = false;
        self
    }

    /// Execute `plan`, returning the root intermediate or a typed error.
    ///
    /// Offload failure is not an error: when an injected fault schedule
    /// (or a deadline) kills a stage terminally, the executor degrades
    /// gracefully — it records the downgrade on the card (stats counter
    /// plus a `Downgraded` trace event) and finishes the plan with the
    /// CPU operators, bit-identical to the accelerated result. Only
    /// scheduler-wide conditions (stalls, bad submissions) still panic,
    /// exactly as the blocking `wait` always has.
    pub fn run(&mut self, plan: &Plan) -> Result<Intermediate, ExecError> {
        if !self.pipelined || self.accelerator.is_none() {
            return self.run_walk(plan);
        }
        let request = PipelineRequest::from_plan(plan, self.catalog)?;
        let Some(acc) = self.accelerator.as_mut() else {
            unreachable!("accelerator presence checked above")
        };
        let mut handle = acc.try_submit_plan(request)?;
        match handle.try_wait() {
            Ok(result) => Ok(result),
            Err(err) if err.failed_job().is_some() => {
                handle.record_downgrade();
                drop(handle);
                self.run_on_cpu(plan)
            }
            Err(err) => panic!("card cannot make progress: {err}"),
        }
    }

    /// Finish `plan` with the CPU operators regardless of an attached
    /// accelerator — the graceful-degradation tail of [`run`] and of the
    /// operator-at-a-time offload arms.
    fn run_on_cpu(&mut self, plan: &Plan) -> Result<Intermediate, ExecError> {
        let acc = self.accelerator.take();
        let result = self.run_walk(plan);
        self.accelerator = acc;
        result
    }

    /// The materializing tree walk: CPU operators, or (without
    /// `pipelined`) one blocking offload per select/join.
    fn run_walk(&mut self, plan: &Plan) -> Result<Intermediate, ExecError> {
        match plan {
            Plan::ScanColumn { table, column } => {
                let t = self
                    .catalog
                    .table(table)
                    .ok_or_else(|| ExecError::UnknownTable(table.clone()))?;
                let c = t.column(column).ok_or_else(|| ExecError::UnknownColumn {
                    table: table.clone(),
                    column: column.clone(),
                })?;
                Ok(Intermediate::Column(c.data.clone()))
            }
            Plan::Select { input, lo, hi } => {
                let key = scan_key(input);
                let col = self.run_walk(input)?.into_column("select input")?;
                if col.as_u32().is_none() {
                    return Err(ExecError::Type {
                        context: "select input",
                        expected: "u32 column",
                        got: col.type_name(),
                    });
                }
                let cands = match self.accelerator.as_mut() {
                    Some(acc) => {
                        let Some(shared) = col.u32_shared() else {
                            unreachable!("u32 type checked above")
                        };
                        // Zero-copy: the request shares the catalog
                        // column's allocation with the card.
                        let req = OffloadRequest::select(*lo, *hi)
                            .on_shared(shared)
                            .keyed(key);
                        let mut handle = acc.submit(req);
                        match handle.try_wait() {
                            Ok((JobOutput::Selection(v), _)) => v,
                            Ok((other, _)) => {
                                unreachable!("selection returned {other:?}")
                            }
                            Err(err) if err.failed_job().is_some() => {
                                handle.record_downgrade();
                                ops::range_select(&col, *lo, *hi, self.threads)
                                    .into()
                            }
                            Err(err) => {
                                panic!("card cannot make progress: {err}")
                            }
                        }
                    }
                    None => ops::range_select(&col, *lo, *hi, self.threads).into(),
                };
                Ok(Intermediate::Candidates(cands))
            }
            Plan::Project { input, candidates } => {
                let col = self.run_walk(input)?.into_column("project input")?;
                let cands =
                    self.run_walk(candidates)?.into_candidates("project candidates")?;
                Ok(Intermediate::Column(ops::project(&col, &cands)))
            }
            Plan::Join { left, right } => {
                let (s_key, l_key) = (scan_key(left), scan_key(right));
                let build = self.run_walk(left)?.into_column("join build side")?;
                let probe = self.run_walk(right)?.into_column("join probe side")?;
                if build.as_u32().is_none() || probe.as_u32().is_none() {
                    let bad = if build.as_u32().is_none() { &build } else { &probe };
                    return Err(ExecError::Type {
                        context: "join input",
                        expected: "u32 column",
                        got: bad.type_name(),
                    });
                }
                let pairs = match self.accelerator.as_mut() {
                    Some(acc) => {
                        let (Some(build_shared), Some(probe_shared)) =
                            (build.u32_shared(), probe.u32_shared())
                        else {
                            unreachable!("u32 types checked above")
                        };
                        let req = OffloadRequest::join_shared(build_shared, probe_shared)
                            .keyed(s_key)
                            .probe_keyed(l_key);
                        let mut handle = acc.submit(req);
                        match handle.try_wait() {
                            Ok((JobOutput::Join(pairs), _)) => pairs,
                            Ok((other, _)) => {
                                unreachable!("join returned {other:?}")
                            }
                            Err(err) if err.failed_job().is_some() => {
                                handle.record_downgrade();
                                ops::hash_join(&build, &probe, self.threads)
                                    .into()
                            }
                            Err(err) => {
                                panic!("card cannot make progress: {err}")
                            }
                        }
                    }
                    None => ops::hash_join(&build, &probe, self.threads).into(),
                };
                Ok(Intermediate::Pairs(pairs))
            }
            Plan::JoinSide { join, left_side } => {
                let pairs = self.run_walk(join)?.into_pairs("join_side input")?;
                Ok(Intermediate::Candidates(
                    pairs
                        .iter()
                        .map(|&(l, r)| if *left_side { l } else { r })
                        .collect(),
                ))
            }
            Plan::Aggregate { input, kind } => {
                let col = self.run_walk(input)?.into_column("aggregate input")?;
                // Validated against the same table the pipeline lowering
                // uses, so errors compare equal across paths.
                if let Some(expected) = kind.expected_input() {
                    if expected != col.type_name() {
                        return Err(ExecError::Type {
                            context: "aggregate kind",
                            expected,
                            got: col.type_name(),
                        });
                    }
                }
                Ok(Intermediate::Scalar(ops::aggregate(&col, *kind)))
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::db::column::{Column, Table};

    fn catalog() -> Catalog {
        let mut cat = Catalog::new();
        cat.register(Table::new(
            "orders",
            vec![
                Column::u32("okey", vec![1, 2, 3, 4, 5]),
                Column::u32("cust", vec![10, 20, 10, 30, 20]),
                Column::f32("total", vec![5.0, 15.0, 25.0, 35.0, 45.0]),
            ],
        ));
        cat.register(Table::new(
            "customers",
            vec![Column::u32("ckey", vec![10, 20, 30])],
        ));
        cat
    }

    #[test]
    fn select_project_aggregate_pipeline() {
        let cat = catalog();
        let mut ex = Executor::cpu(&cat, 2);
        // SELECT sum(total) FROM orders WHERE okey BETWEEN 2 AND 4
        let plan = Plan::scan("orders", "total").project(
            Plan::scan("orders", "okey").select(2, 4),
        );
        let col = ex.run(&plan).unwrap().expect_column();
        assert_eq!(col, ColumnData::F32(vec![15.0, 25.0, 35.0].into()));
        let agg = ex
            .run(&plan.clone().aggregate(AggKind::SumF32))
            .unwrap()
            .expect_scalar();
        assert_eq!(agg, AggResult::F64(75.0));
    }

    #[test]
    fn join_and_sides() {
        let cat = catalog();
        let mut ex = Executor::cpu(&cat, 1);
        // customers ⋈ orders ON ckey = cust
        let join =
            Plan::scan("customers", "ckey").join(Plan::scan("orders", "cust"));
        let pairs = ex.run(&join).unwrap().expect_pairs();
        assert_eq!(pairs.len(), 5, "every order has a customer");
        // Project order totals of customer 20's orders.
        let plan = Plan::scan("orders", "total")
            .project(join.join_side(false));
        let col = ex.run(&plan).unwrap().expect_column();
        assert_eq!(col.len(), 5);
    }

    #[test]
    fn accelerated_executor_reuses_resident_columns() {
        let cat = catalog();
        let mut acc = FpgaAccelerator::new(crate::hbm::HbmConfig::default());
        // Same scan twice on one accelerator: the second pipeline must hit
        // the coordinator's column cache via the (table, column) key.
        let plan = Plan::scan("orders", "total")
            .project(Plan::scan("orders", "okey").select(2, 4));
        let a = Executor::accelerated(&cat, 2, &mut acc).run(&plan).unwrap();
        let b = Executor::accelerated(&cat, 2, &mut acc).run(&plan).unwrap();
        assert_eq!(a, b);
        let stats = acc.stats();
        assert_eq!(stats.completed(), 2);
        assert_eq!(stats.cache.hits, 1, "repeat scan must be HBM-resident");
    }

    #[test]
    fn pipelined_and_blocking_paths_agree() {
        let cat = catalog();
        let plan = Plan::scan("customers", "ckey")
            .join(Plan::scan("orders", "cust"))
            .join_side(true);
        let cpu = Executor::cpu(&cat, 2).run(&plan).unwrap();
        let mut acc_a = FpgaAccelerator::new(crate::hbm::HbmConfig::default());
        let piped = Executor::accelerated(&cat, 2, &mut acc_a).run(&plan).unwrap();
        let mut acc_b = FpgaAccelerator::new(crate::hbm::HbmConfig::default());
        let blocking = Executor::accelerated(&cat, 2, &mut acc_b)
            .operator_at_a_time()
            .run(&plan)
            .unwrap();
        // Candidate order can differ between paths; compare as sets.
        let norm = |i: Intermediate| {
            let mut v = i.expect_candidates().to_vec();
            v.sort_unstable();
            v
        };
        let want = norm(cpu);
        assert_eq!(norm(piped), want);
        assert_eq!(norm(blocking), want);
    }

    #[test]
    fn executor_degrades_to_cpu_after_terminal_faults() {
        use crate::fault::{Fault, FaultPlan, ScheduledFault};
        use crate::hbm::shim::ENGINE_PORTS;

        let mut cat = Catalog::new();
        cat.register(Table::new(
            "big",
            vec![Column::u32("okey", (0..400_000).collect())],
        ));
        let plan = Plan::scan("big", "okey").select(10_000, 350_000);
        let want = Executor::cpu(&cat, 2).run(&plan).unwrap();

        // Kill every engine port on a 1 µs grid from t = 0: the offload
        // can never hold an engine long enough, so it faults out after
        // MAX_ATTEMPTS and the executor must finish on the CPU.
        let mut faults = Vec::new();
        for step in 0..4_000u32 {
            for port in 0..ENGINE_PORTS {
                faults.push(ScheduledFault {
                    at: 1e-9 + f64::from(step) * 1e-6,
                    card: 0,
                    fault: Fault::EngineFault { port },
                });
            }
        }
        let armed = FaultPlan { mix: "custom", seed: 0, cards: 1, faults };

        for pipelined in [true, false] {
            let mut acc = FpgaAccelerator::new(crate::hbm::HbmConfig::default());
            acc.set_tracing(true);
            acc.arm_faults(&armed);
            let mut ex = Executor::accelerated(&cat, 2, &mut acc);
            if !pipelined {
                ex = ex.operator_at_a_time();
            }
            let got = ex.run(&plan).unwrap();
            assert_eq!(got, want, "degraded result must stay bit-identical");
            assert_eq!(acc.downgrades(), 1, "pipelined={pipelined}");
            assert_eq!(
                acc.retries(),
                u64::from(crate::fault::MAX_ATTEMPTS - 1),
                "terminal failure retries all but the last attempt"
            );
            let downgraded = acc
                .take_trace()
                .into_iter()
                .any(|e| matches!(e, crate::trace::Event::Downgraded { .. }));
            assert!(downgraded, "degradation must reach the trace");
        }
    }

    #[test]
    fn unknown_names_are_typed_errors() {
        let cat = catalog();
        let mut ex = Executor::cpu(&cat, 1);
        assert_eq!(
            ex.run(&Plan::scan("nope", "x")).unwrap_err(),
            ExecError::UnknownTable("nope".into())
        );
        assert_eq!(
            ex.run(&Plan::scan("orders", "missing")).unwrap_err(),
            ExecError::UnknownColumn {
                table: "orders".into(),
                column: "missing".into()
            }
        );
        // The accelerated (pipeline) path maps onto the same variants.
        let mut acc = FpgaAccelerator::new(crate::hbm::HbmConfig::default());
        assert_eq!(
            Executor::accelerated(&cat, 1, &mut acc)
                .run(&Plan::scan("nope", "x"))
                .unwrap_err(),
            ExecError::UnknownTable("nope".into())
        );
    }

    #[test]
    fn type_misuse_is_a_typed_error_not_a_panic() {
        let cat = catalog();
        let mut ex = Executor::cpu(&cat, 1);
        // Selecting over an f32 column.
        let err = ex
            .run(&Plan::scan("orders", "total").select(1, 2))
            .unwrap_err();
        assert!(matches!(err, ExecError::Type { .. }), "{err}");
        // Aggregating a candidate list.
        let err = ex
            .run(
                &Plan::scan("orders", "okey")
                    .select(1, 3)
                    .aggregate(AggKind::Count),
            )
            .unwrap_err();
        assert_eq!(
            err,
            ExecError::Type {
                context: "aggregate input",
                expected: "column",
                got: "candidate list"
            }
        );
        // The pipelined path reports the identical payload for this plan.
        let mut acc = FpgaAccelerator::new(crate::hbm::HbmConfig::default());
        let piped_err = Executor::accelerated(&cat, 1, &mut acc)
            .run(
                &Plan::scan("orders", "okey")
                    .select(1, 3)
                    .aggregate(AggKind::Count),
            )
            .unwrap_err();
        assert_eq!(piped_err, err, "error payloads must match across paths");
        // Wrong aggregate kind for the element type.
        let err = ex
            .run(&Plan::scan("orders", "okey").aggregate(AggKind::SumF32))
            .unwrap_err();
        assert!(matches!(err, ExecError::Type { .. }), "{err}");
    }
}
