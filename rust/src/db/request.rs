//! Typed offload requests: the one place where work crossing the
//! DBMS↔card boundary is shaped and validated.
//!
//! The paper's §III/§V integration story is about this boundary — what
//! crosses OpenCAPI, when, and what stays resident in HBM. An
//! [`OffloadRequest`] captures one operator's crossing declaratively:
//!
//! ```ignore
//! let handle = acc.submit(
//!     OffloadRequest::select(100, 999)
//!         .on(&column)
//!         .key("lineitem", "qty")   // HBM residency identity
//!         .engines(8),
//! );
//! ```
//!
//! Every rule that used to be scattered over the old `offload_*`
//! entry-point family lives here:
//!
//! * **engine clamps** — selection/SGD engines are capped at the 14 shim
//!   ports; join engines at 7 (each drives a read port and a write port);
//! * **collision handling** — chosen from the build side's uniqueness
//!   unless the caller forces a bitstream variant with
//!   [`collisions`](OffloadRequest::collisions);
//! * **residency** — per-request `(table, column)` keys name inputs for
//!   the coordinator's HBM-resident cache; a repeated key skips its
//!   copy-in while the column stays cached. Anonymous inputs (no key) are
//!   copied every time;
//! * **shape checks** — a selection must carry data, an SGD grid must be
//!   non-empty and its feature matrix rectangular.
//!
//! Requests lower to the coordinator's internal `JobSpec` at submission;
//! validation failures surface as [`RequestError`] from
//! `FpgaAccelerator::try_submit` (or a panic from the ergonomic
//! `submit`).

use std::sync::Arc;

use crate::coordinator::{ColumnKey, JobKind, JobSpec};
use crate::engines::sgd::SgdHyperParams;
use crate::hbm::shim::ENGINE_PORTS;

/// Most engines a join request may occupy: each join engine holds a read
/// port and a write port, so 14 ports carry 7 engines.
pub const MAX_JOIN_ENGINES: usize = ENGINE_PORTS / 2;

/// Why a request failed validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// The request is missing its payload (e.g. `select` without `.on`).
    MissingData(&'static str),
    /// An SGD request with an empty hyperparameter grid.
    EmptyGrid,
    /// Payload dimensions are inconsistent.
    BadShape(String),
    /// The accelerator's bounded admission window is full: `in_flight`
    /// jobs already queued or running against a bound of `bound`
    /// (`FpgaAccelerator::with_admission_bound`). Backpressure, not a
    /// validation error — retry after draining completed work.
    Overloaded { in_flight: usize, bound: usize },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::MissingData(what) => write!(f, "missing data: {what}"),
            RequestError::EmptyGrid => {
                write!(f, "sgd request needs a non-empty hyperparameter grid")
            }
            RequestError::BadShape(why) => write!(f, "bad payload shape: {why}"),
            RequestError::Overloaded { in_flight, bound } => write!(
                f,
                "accelerator overloaded: {in_flight} jobs in flight \
                 against an admission bound of {bound}"
            ),
        }
    }
}

impl std::error::Error for RequestError {}

#[derive(Debug, Clone)]
enum Payload {
    Select {
        data: Option<Arc<[u32]>>,
        lo: u32,
        hi: u32,
        key: Option<ColumnKey>,
    },
    Join {
        s: Arc<[u32]>,
        l: Arc<[u32]>,
        s_key: Option<ColumnKey>,
        l_key: Option<ColumnKey>,
        /// `None`: decide from the build side's uniqueness at submission.
        collisions: Option<bool>,
    },
    Sgd {
        features: Arc<[f32]>,
        labels: Arc<[f32]>,
        n_features: usize,
        grid: Vec<SgdHyperParams>,
        key: Option<ColumnKey>,
    },
}

/// A typed, validated description of one offload. Build with
/// [`select`](OffloadRequest::select), [`join`](OffloadRequest::join) or
/// [`sgd`](OffloadRequest::sgd), refine with the chainable setters, then
/// hand to `FpgaAccelerator::submit` for an async `JobHandle`.
#[derive(Debug, Clone)]
pub struct OffloadRequest {
    payload: Payload,
    /// Engine cap; `None` inherits the accelerator's default.
    engines: Option<usize>,
    client: usize,
    /// Card-seconds this job may spend *queued* before the coordinator
    /// expires it (see `JobSpec::deadline`); `None` disables the check.
    deadline: Option<f64>,
}

impl OffloadRequest {
    /// Range selection `lo..=hi`; attach the column with
    /// [`on`](OffloadRequest::on).
    pub fn select(lo: u32, hi: u32) -> Self {
        Self {
            payload: Payload::Select { data: None, lo, hi, key: None },
            engines: None,
            client: 0,
            deadline: None,
        }
    }

    /// Hash join: build side `s`, probe side `l`. Collision handling is
    /// auto-detected from `s` unless forced with
    /// [`collisions`](OffloadRequest::collisions). Copies each slice once
    /// into a shared column; callers already holding `Arc` columns (the
    /// plan executor) use [`join_shared`](OffloadRequest::join_shared).
    pub fn join(s: &[u32], l: &[u32]) -> Self {
        Self::join_shared(s.into(), l.into())
    }

    /// Zero-copy [`join`](OffloadRequest::join): the shared columns are
    /// handed over without copying their bytes.
    pub fn join_shared(s: Arc<[u32]>, l: Arc<[u32]>) -> Self {
        Self {
            payload: Payload::Join {
                s,
                l,
                s_key: None,
                l_key: None,
                collisions: None,
            },
            engines: None,
            client: 0,
            deadline: None,
        }
    }

    /// GLM hyperparameter grid over one dataset (row-major `features`,
    /// one label per sample). Copies the dataset once into shared
    /// columns; see [`sgd_shared`](OffloadRequest::sgd_shared).
    pub fn sgd(
        features: &[f32],
        labels: &[f32],
        n_features: usize,
        grid: &[SgdHyperParams],
    ) -> Self {
        Self::sgd_shared(features.into(), labels.into(), n_features, grid.to_vec())
    }

    /// Zero-copy [`sgd`](OffloadRequest::sgd).
    pub fn sgd_shared(
        features: Arc<[f32]>,
        labels: Arc<[f32]>,
        n_features: usize,
        grid: Vec<SgdHyperParams>,
    ) -> Self {
        Self {
            payload: Payload::Sgd { features, labels, n_features, grid, key: None },
            engines: None,
            client: 0,
            deadline: None,
        }
    }

    /// Attach the selection's input column (one copy into a shared
    /// column). Panics on non-selection requests (join/SGD carry their
    /// payloads in their constructors).
    pub fn on(self, data: &[u32]) -> Self {
        self.on_shared(data.into())
    }

    /// Zero-copy [`on`](OffloadRequest::on): attach an already-shared
    /// column without copying its bytes.
    pub fn on_shared(mut self, data: Arc<[u32]>) -> Self {
        match &mut self.payload {
            Payload::Select { data: slot, .. } => *slot = Some(data),
            other => panic!(
                ".on(data) applies to select requests, not {}",
                payload_name(other)
            ),
        }
        self
    }

    /// Residency identity of the primary input (selection column, join
    /// build side, SGD dataset): a repeated key skips copy-in while the
    /// column stays HBM-resident.
    pub fn key(self, table: impl Into<String>, column: impl Into<String>) -> Self {
        self.keyed(Some(ColumnKey::new(table, column)))
    }

    /// [`key`](OffloadRequest::key) with an optional identity — handy for
    /// callers (like the plan executor) that only sometimes have one.
    pub fn keyed(mut self, key: Option<ColumnKey>) -> Self {
        match &mut self.payload {
            Payload::Select { key: slot, .. } => *slot = key,
            Payload::Join { s_key, .. } => *s_key = key,
            Payload::Sgd { key: slot, .. } => *slot = key,
        }
        self
    }

    /// Residency identity of the join's probe side.
    pub fn probe_key(
        self,
        table: impl Into<String>,
        column: impl Into<String>,
    ) -> Self {
        self.probe_keyed(Some(ColumnKey::new(table, column)))
    }

    /// [`probe_key`](OffloadRequest::probe_key) with an optional identity.
    /// Panics on non-join requests.
    pub fn probe_keyed(mut self, key: Option<ColumnKey>) -> Self {
        match &mut self.payload {
            Payload::Join { l_key, .. } => *l_key = key,
            other => panic!(
                ".probe_keyed applies to join requests, not {}",
                payload_name(other)
            ),
        }
        self
    }

    /// Force the collision-handling bitstream variant instead of deriving
    /// it from the build side. Panics on non-join requests.
    pub fn collisions(mut self, handle: bool) -> Self {
        match &mut self.payload {
            Payload::Join { collisions, .. } => *collisions = Some(handle),
            other => panic!(
                ".collisions applies to join requests, not {}",
                payload_name(other)
            ),
        }
        self
    }

    /// Cap the compute engines this request may occupy. Clamped at
    /// submission to the card's limits (≤ 14; joins ≤ 7).
    pub fn engines(mut self, n: usize) -> Self {
        self.engines = Some(n);
        self
    }

    /// Tag the submitting client (reporting only).
    pub fn client(mut self, id: usize) -> Self {
        self.client = id;
        self
    }

    /// Expire the job if it is still *queued* `budget` card-seconds after
    /// submission: the handle's wait then returns
    /// [`CoordinatorError::DeadlineExceeded`](crate::coordinator::CoordinatorError)
    /// instead of blocking. Dispatch is non-preemptive — a job that made
    /// it onto engines always runs its stage to the next event — and a
    /// non-finite or non-positive budget is already expired at the first
    /// scheduling point.
    pub fn deadline(mut self, budget: f64) -> Self {
        self.deadline = Some(budget);
        self
    }

    /// The workload kind this request describes.
    pub fn kind_name(&self) -> &'static str {
        payload_name(&self.payload)
    }

    /// Check the request without submitting it. `submit` runs the same
    /// checks and panics; `try_submit` surfaces this error.
    pub fn validate(&self) -> Result<(), RequestError> {
        match &self.payload {
            Payload::Select { data, .. } => {
                if data.is_none() {
                    return Err(RequestError::MissingData(
                        "select request needs .on(column)",
                    ));
                }
            }
            Payload::Join { .. } => {}
            Payload::Sgd { features, labels, n_features, grid, .. } => {
                if grid.is_empty() {
                    return Err(RequestError::EmptyGrid);
                }
                if *n_features == 0 {
                    return Err(RequestError::BadShape(
                        "n_features must be positive".into(),
                    ));
                }
                if features.len() != labels.len() * n_features {
                    return Err(RequestError::BadShape(format!(
                        "features len {} != {} samples x {} features",
                        features.len(),
                        labels.len(),
                        n_features
                    )));
                }
            }
        }
        Ok(())
    }

    /// Lower to the coordinator's job model, applying every boundary rule
    /// in one place: shape validation, engine clamps, collision detection,
    /// per-input residency keys.
    pub(crate) fn into_spec(self, default_engines: usize) -> Result<JobSpec, RequestError> {
        self.validate()?;
        let engine_limit = match &self.payload {
            Payload::Join { .. } => MAX_JOIN_ENGINES,
            _ => ENGINE_PORTS,
        };
        let engines = self.engines.unwrap_or(default_engines).clamp(1, engine_limit);
        let (kind, keys) = match self.payload {
            Payload::Select { data, lo, hi, key } => {
                let Some(data) = data else {
                    unreachable!("validate rejects a select without data")
                };
                (JobKind::Selection { data, lo, hi }, vec![key])
            }
            Payload::Join { s, l, s_key, l_key, collisions } => {
                let handle_collisions =
                    collisions.unwrap_or_else(|| !build_side_is_unique(&s));
                (JobKind::Join { s, l, handle_collisions }, vec![s_key, l_key])
            }
            Payload::Sgd { features, labels, n_features, grid, key } => (
                JobKind::Sgd { features, labels, n_features, grid },
                vec![key],
            ),
        };
        Ok(JobSpec::new(kind)
            .with_keys(keys)
            .with_max_engines(engines)
            .with_client(self.client)
            .with_deadline(self.deadline))
    }
}

fn payload_name(p: &Payload) -> &'static str {
    match p {
        Payload::Select { .. } => "select",
        Payload::Join { .. } => "join",
        Payload::Sgd { .. } => "sgd",
    }
}

pub(crate) use crate::coordinator::job::build_side_is_unique;

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::engines::sgd::GlmTask;

    fn grid1() -> Vec<SgdHyperParams> {
        vec![SgdHyperParams {
            task: GlmTask::Ridge,
            alpha: 0.05,
            lambda: 0.0,
            minibatch: 16,
            epochs: 2,
        }]
    }

    #[test]
    fn select_lowering_carries_key_and_clamps_engines() {
        let spec = OffloadRequest::select(10, 20)
            .on(&[1, 15, 30])
            .key("t", "c")
            .engines(99)
            .client(3)
            .into_spec(ENGINE_PORTS)
            .unwrap();
        assert_eq!(spec.max_engines, ENGINE_PORTS, "clamped to the 14 ports");
        assert_eq!(spec.client, 3);
        assert_eq!(spec.inputs.len(), 1);
        assert_eq!(spec.inputs[0].key.as_ref().unwrap().to_string(), "t.c");
        match spec.kind {
            JobKind::Selection { ref data, lo, hi } => {
                assert_eq!(data[..], [1, 15, 30]);
                assert_eq!((lo, hi), (10, 20));
            }
            ref other => panic!("wrong kind {}", other.name()),
        }
    }

    #[test]
    fn join_clamps_to_seven_engines_and_detects_collisions() {
        // Duplicate build keys: collision handling must switch on.
        let spec = OffloadRequest::join(&[1, 2, 2], &[1, 2, 3])
            .engines(99)
            .into_spec(ENGINE_PORTS)
            .unwrap();
        assert_eq!(spec.max_engines, MAX_JOIN_ENGINES);
        match spec.kind {
            JobKind::Join { handle_collisions, .. } => assert!(handle_collisions),
            ref other => panic!("wrong kind {}", other.name()),
        }

        // Unique build side: off by default, but the caller can force it.
        let auto = OffloadRequest::join(&[1, 2, 3], &[1])
            .into_spec(ENGINE_PORTS)
            .unwrap();
        let forced = OffloadRequest::join(&[1, 2, 3], &[1])
            .collisions(true)
            .into_spec(ENGINE_PORTS)
            .unwrap();
        match (auto.kind, forced.kind) {
            (
                JobKind::Join { handle_collisions: a, .. },
                JobKind::Join { handle_collisions: f, .. },
            ) => {
                assert!(!a);
                assert!(f);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn default_engines_inherited_from_accelerator() {
        let spec = OffloadRequest::select(0, 1)
            .on(&[1])
            .into_spec(4)
            .unwrap();
        assert_eq!(spec.max_engines, 4);
    }

    #[test]
    fn deadline_rides_through_to_the_spec() {
        let spec = OffloadRequest::select(0, 1)
            .on(&[1])
            .deadline(2e-3)
            .into_spec(4)
            .unwrap();
        assert_eq!(spec.deadline, Some(2e-3));
        let spec = OffloadRequest::select(0, 1).on(&[1]).into_spec(4).unwrap();
        assert_eq!(spec.deadline, None);
    }

    #[test]
    fn select_without_data_is_rejected() {
        let err = OffloadRequest::select(0, 1).validate().unwrap_err();
        assert!(matches!(err, RequestError::MissingData(_)));
    }

    #[test]
    fn sgd_shape_checks() {
        assert!(matches!(
            OffloadRequest::sgd(&[0.0; 8], &[0.0; 2], 4, &[]).validate(),
            Err(RequestError::EmptyGrid)
        ));
        assert!(matches!(
            OffloadRequest::sgd(&[0.0; 7], &[0.0; 2], 4, &grid1()).validate(),
            Err(RequestError::BadShape(_))
        ));
        assert!(OffloadRequest::sgd(&[0.0; 8], &[0.0; 2], 4, &grid1())
            .validate()
            .is_ok());
    }

    #[test]
    #[should_panic(expected = ".on(data) applies to select requests")]
    fn on_rejects_non_select() {
        let _ = OffloadRequest::join(&[1], &[2]).on(&[3]);
    }

    #[test]
    #[should_panic(expected = ".probe_keyed applies to join requests")]
    fn probe_key_rejects_non_join() {
        let _ = OffloadRequest::select(0, 1).probe_key("t", "c");
    }
}
