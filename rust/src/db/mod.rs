//! A miniature column-oriented, in-memory DBMS in the mould of MonetDB —
//! the integration substrate of the paper (§II/§III).
//!
//! The paper's accelerators are not free-standing: they are *operators*
//! inside an operator-at-a-time columnar engine, invoked through a
//! UDF-style hook, with all the data-movement consequences that implies
//! (host columns must be copied to HBM, results copied back and
//! re-materialized as candidate lists). This module reproduces that
//! architecture — and the pipeline API that removes the round-trips:
//!
//! * [`column`] — BAT-style typed columns, tables, and the catalog.
//!   **Ownership rule:** columns are shared, immutable `Arc` slices
//!   (`Arc<[u32]>` / `Arc<[f32]>`); every boundary crossing — plan
//!   lowering, offload payloads, published intermediates, results taken
//!   back — clones a handle, never the bytes (see [`column`]'s docs);
//! * [`ops`] — the relational operators (scan, range-select, hash join,
//!   project, aggregate), all late-materializing via candidate lists;
//! * [`exec`] — the plan executor: CPU operators with typed
//!   [`ExecError`]s; accelerated plans route whole through the pipeline
//!   API (the historical blocking per-operator walk survives as
//!   `Executor::operator_at_a_time` for measuring what pipelining saves);
//! * [`request`] — the typed [`OffloadRequest`] builder for single
//!   operators: payload, engine caps, collision handling, and per-input
//!   `(table, column)` residency keys, validated in one place;
//! * [`pipeline`] — the whole-plan boundary: [`PipelineRequest`] lowers a
//!   [`Plan`] into a dependency-linked DAG of offload stages (validated
//!   as [`PipelineError`]); `FpgaAccelerator::submit_plan` returns an
//!   async [`PipelineHandle`] whose dependent stages consume parent
//!   outputs directly from HBM — pinned transient cache entries instead
//!   of host round-trips — with per-stage copy-in reported in a
//!   [`PipelineReport`];
//! * [`udf`] — the accelerator hook: [`FpgaAccelerator::submit`] /
//!   `submit_plan` enqueue work on the card's coordinator and return
//!   async handles ([`JobHandle`] / [`PipelineHandle`]), so the executor
//!   and multi-query clients keep several operators — or several whole
//!   queries — in flight; each completed job reports the timing breakdown
//!   (copy-in / execute / copy-out) the end-to-end figures need.

// DBMS-layer invariant: no `unwrap`/`expect` in non-test code (see
// clippy.toml) — broken invariants get a `let`-`else` with a message
// naming what was violated, everything else a typed error.
#![deny(clippy::disallowed_methods)]

pub mod column;
pub mod exec;
pub mod ops;
pub mod pipeline;
pub mod request;
pub mod udf;

pub use column::{Catalog, Column, ColumnData, Table};
pub use exec::{ExecError, Executor, Intermediate, Plan};
pub use pipeline::{PipelineError, PipelineHandle, PipelineReport, PipelineRequest};
pub use request::{OffloadRequest, RequestError, MAX_JOIN_ENGINES};
pub use udf::{FpgaAccelerator, JobHandle, OffloadTiming};
