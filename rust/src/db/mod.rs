//! A miniature column-oriented, in-memory DBMS in the mould of MonetDB —
//! the integration substrate of the paper (§II/§III).
//!
//! The paper's accelerators are not free-standing: they are *operators*
//! inside an operator-at-a-time columnar engine, invoked through a
//! UDF-style hook, with all the data-movement consequences that implies
//! (host columns must be copied to HBM, results copied back and
//! re-materialized as candidate lists). This module reproduces that
//! architecture:
//!
//! * [`column`] — BAT-style typed columns, tables, and the catalog;
//! * [`ops`] — the relational operators (scan, range-select, hash join,
//!   project, aggregate), all late-materializing via candidate lists;
//! * [`exec`] — a small operator-at-a-time plan executor with a builder
//!   API;
//! * [`request`] — the typed [`OffloadRequest`] builder: payload, engine
//!   caps, collision handling, and per-input `(table, column)` residency
//!   keys, validated in one place;
//! * [`udf`] — the accelerator hook: [`FpgaAccelerator::submit`] enqueues
//!   a request on the card's coordinator and returns an async
//!   [`JobHandle`] (`poll`/`wait`), so the executor and multi-query
//!   clients keep several operators in flight; each completed job reports
//!   the timing breakdown (copy-in / execute / copy-out) the end-to-end
//!   figures need.

pub mod column;
pub mod exec;
pub mod ops;
pub mod request;
pub mod udf;

pub use column::{Catalog, Column, ColumnData, Table};
pub use exec::{Executor, Plan};
pub use request::{OffloadRequest, RequestError, MAX_JOIN_ENGINES};
pub use udf::{FpgaAccelerator, JobHandle, OffloadTiming};
