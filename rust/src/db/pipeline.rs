//! Whole-plan offload: lower a [`Plan`] into a dependency-linked DAG of
//! offload stages with HBM-resident intermediates.
//!
//! The paper's MonetDB integration (§II, §VI) pays operator-at-a-time
//! materialization in full: every offloaded operator round-trips its
//! intermediate through the host, even when the next operator consumes it
//! immediately on the card. A [`PipelineRequest`] removes that
//! round-trip. Lowered from a [`Plan`], it captures the plan's offloadable
//! operators (range selects and hash joins) as `OffloadRequest`-shaped
//! *stages* plus dependency edges between them, and ships the whole DAG
//! to the card in one submission:
//!
//! ```ignore
//! let request = PipelineRequest::from_plan(&plan, &catalog)?;
//! let mut handle = acc.submit_plan(request);   // returns immediately
//! let result = handle.wait();                  // drives the card
//! ```
//!
//! A dependent stage never copies its derived input over OpenCAPI: the
//! parent stage's output is published into the coordinator's column cache
//! as a **pinned transient entry** (never evicted while a dependent is in
//! flight, released on consumption), and positional gathers of base
//! columns happen card-side against resident data. Only base columns that
//! miss the resident cache cross the link. Host-side glue that engines
//! cannot run (final projections, f32 columns, aggregates) is evaluated
//! by the [`PipelineHandle`] once every stage completed.
//!
//! Every plan-boundary rule lives here, surfaced as [`PipelineError`]
//! from [`PipelineRequest::from_plan`] / `FpgaAccelerator::try_submit_plan`:
//!
//! * **unknown tables/columns** — scans are resolved against the catalog
//!   at lowering;
//! * **producer/consumer shape checks** — every operator's input type is
//!   validated (a select cannot consume a candidate list, joins need u32
//!   columns, aggregate kinds must match element types), plus static
//!   length checks between gather sources and candidate domains for
//!   gathers that run card-side (host-side finisher projects keep the
//!   CPU executor's permissive positional semantics, so valid plans
//!   behave identically on both paths);
//! * **engine-cap conflicts** — a per-pipeline cap outside the card's
//!   limits (`1..=14` shim ports) is rejected rather than silently
//!   clamped; join stages are further bounded by the card's 7
//!   read/write-port engine pairs, a physical per-operator limit.
//!
//! Several whole queries co-run: each `submit_plan` enqueues its DAG
//! atomically, and the coordinator's round policy interleaves ready
//! stages from all in-flight pipelines.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use super::column::{Catalog, ColumnData};
use super::exec::{Intermediate, Plan};
use super::ops::{self, AggKind, AggResult};
use super::request::build_side_is_unique;
use super::udf::FpgaAccelerator;
use crate::coordinator::{
    ColumnKey, Coordinator, CoordinatorError, DepExpr, DepInput, JobKind,
    JobOutput, JobRecord, JobSpec,
};
use crate::fleet::RouteQuery;
use crate::hbm::shim::ENGINE_PORTS;

/// Why a plan could not be lowered into (or submitted as) a pipeline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PipelineError {
    /// A scan names a table the catalog does not have.
    UnknownTable(String),
    /// A scan names a column its table does not have.
    UnknownColumn { table: String, column: String },
    /// A producer feeds a consumer the wrong kind of intermediate.
    TypeMismatch {
        context: &'static str,
        expected: &'static str,
        got: &'static str,
    },
    /// A gather source is (statically) shorter than the candidate domain
    /// its positions index — the gather would run off the column.
    ShapeMismatch {
        context: &'static str,
        expected: usize,
        got: usize,
    },
    /// The requested engine cap is outside the card's limits.
    EngineCap { requested: usize, limit: usize },
    /// The static analyzer proved the plan cannot execute (cycle,
    /// dangling dependency, infeasible footprint, …). Carries every
    /// Error-level [`Diagnostic`](crate::analyze::Diagnostic) so callers
    /// can print precise attributions and suggested fixes.
    Rejected(Vec<crate::analyze::Diagnostic>),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::UnknownTable(t) => write!(f, "unknown table '{t}'"),
            PipelineError::UnknownColumn { table, column } => {
                write!(f, "unknown column '{table}.{column}'")
            }
            PipelineError::TypeMismatch { context, expected, got } => {
                write!(f, "{context}: expected {expected}, got {got}")
            }
            PipelineError::ShapeMismatch { context, expected, got } => write!(
                f,
                "{context}: gather source has only {got} rows but its \
                 candidate domain has {expected}"
            ),
            PipelineError::EngineCap { requested, limit } => write!(
                f,
                "engine cap {requested} outside the card's limits (1..={limit})"
            ),
            PipelineError::Rejected(diagnostics) => {
                write!(
                    f,
                    "plan rejected by static analysis ({} error(s))",
                    diagnostics.len()
                )?;
                for d in diagnostics {
                    write!(f, "; {d}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for PipelineError {}

/// The offloadable operator of one stage.
#[derive(Debug, Clone)]
enum StageOp {
    Select { lo: u32, hi: u32 },
    Join,
}

/// One payload slot of a stage. Columns are shared `Arc` slices straight
/// out of the catalog — lowering and submission never copy column bytes.
#[derive(Debug, Clone)]
enum StageInput {
    /// A host base column, named for the resident cache.
    Host { data: Arc<[u32]>, key: ColumnKey },
    /// Derived on the card from earlier stages' outputs.
    Expr(StageExpr),
}

/// Dependency expression over *stage indices* (lowered to job-id
/// [`DepExpr`]s at submission).
#[derive(Debug, Clone)]
enum StageExpr {
    Candidates(usize),
    JoinSide { stage: usize, left: bool },
    Column { data: Arc<[u32]>, key: Option<ColumnKey> },
    Gather { column: Box<StageExpr>, positions: Box<StageExpr> },
}

/// One offload stage: operator plus per-slot inputs.
#[derive(Debug, Clone)]
struct PipelineStage {
    op: StageOp,
    inputs: Vec<StageInput>,
}

/// Static per-stage shape facts for producer/consumer length checks.
#[derive(Debug, Clone, Copy)]
enum StageMeta {
    Select { input_len: Option<usize> },
    Join { s_len: Option<usize>, l_len: Option<usize> },
}

/// Host-side finisher: how the final [`Intermediate`] is assembled from
/// stage outputs and base columns once every stage completed.
#[derive(Debug, Clone)]
enum Finish {
    Base { data: ColumnData, key: ColumnKey },
    SelectStage(usize),
    JoinStage(usize),
    JoinSide { stage: usize, left: bool },
    Project { input: Box<Finish>, candidates: Box<Finish> },
    Aggregate { input: Box<Finish>, kind: AggKind },
}

/// Value type of a lowered plan node, for producer/consumer validation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum VType {
    ColU32,
    ColF32,
    Cands,
    Pairs,
    Scalar,
}

fn vname(t: VType) -> &'static str {
    match t {
        VType::ColU32 => "u32 column",
        VType::ColF32 => "f32 column",
        VType::Cands => "candidate list",
        VType::Pairs => "join pairs",
        VType::Scalar => "scalar",
    }
}

/// A whole query plan lowered for submission: the stage DAG plus the
/// host-side finisher. Build with [`from_plan`](PipelineRequest::from_plan),
/// refine with the chainable setters, then hand to
/// `FpgaAccelerator::submit_plan` for a [`PipelineHandle`].
#[derive(Debug, Clone)]
pub struct PipelineRequest {
    stages: Vec<PipelineStage>,
    finish: Finish,
    engines: Option<usize>,
    client: usize,
    deadline: Option<f64>,
}

impl PipelineRequest {
    /// Lower `plan` against `catalog`, running every validation rule of
    /// the plan→card boundary (see the module docs).
    pub fn from_plan(plan: &Plan, catalog: &Catalog) -> Result<Self, PipelineError> {
        let mut lowerer = Lowerer { catalog, stages: Vec::new(), metas: Vec::new() };
        let (finish, _) = lowerer.lower(plan)?;
        Ok(Self {
            stages: lowerer.stages,
            finish,
            engines: None,
            client: 0,
            deadline: None,
        })
    }

    /// Cap the compute engines each stage may occupy. Unlike the
    /// per-operator `OffloadRequest::engines` (which clamps silently),
    /// a cap outside the card's limits (`1..=14`) is a validation error.
    /// Join stages pair a read and a write port, so their effective cap
    /// is additionally bounded by the 7 join-engine pairs — a physical
    /// per-operator limit, not a request error.
    pub fn engines(mut self, n: usize) -> Self {
        self.engines = Some(n);
        self
    }

    /// Tag the submitting client (reporting only).
    pub fn client(mut self, id: usize) -> Self {
        self.client = id;
        self
    }

    /// Give every stage a queueing budget of `budget` card-seconds from
    /// submission. Deadlines are non-preemptive: a stage still *waiting*
    /// when its budget expires fails with
    /// [`CoordinatorError::DeadlineExceeded`] (and the failure cascades
    /// down the DAG), while a stage already copying or computing runs to
    /// completion and delivers late instead. A non-finite or non-positive
    /// budget is already expired.
    pub fn deadline(mut self, budget: f64) -> Self {
        self.deadline = Some(budget);
        self
    }

    /// Offload stages this plan lowers to (0 for pure host plans).
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Operator names of the stages, in dependency (submission) order.
    pub fn stage_names(&self) -> Vec<&'static str> {
        self.stages
            .iter()
            .map(|s| match s.op {
                StageOp::Select { .. } => "selection",
                StageOp::Join => "join",
            })
            .collect()
    }

    /// Check the request without submitting it (`from_plan` already
    /// validated the plan shape; this re-checks submission-time knobs).
    pub fn validate(&self) -> Result<(), PipelineError> {
        if let Some(n) = self.engines {
            if n == 0 || n > ENGINE_PORTS {
                return Err(PipelineError::EngineCap {
                    requested: n,
                    limit: ENGINE_PORTS,
                });
            }
        }
        Ok(())
    }

    /// The plan reduced to what the static analyzer needs: operators,
    /// slot shapes (row counts + cache keys), and dependency edges over
    /// stage indices. Column bytes are never copied — only lengths and
    /// keys cross into the facts.
    pub fn facts(&self) -> crate::analyze::PlanFacts {
        use crate::analyze::{ExprFacts, InputFacts, PlanFacts, StageFacts};

        fn expr_facts(e: &StageExpr) -> ExprFacts {
            match e {
                StageExpr::Candidates(stage) => ExprFacts::Candidates(*stage),
                StageExpr::JoinSide { stage, left } => {
                    ExprFacts::JoinSide { stage: *stage, left: *left }
                }
                StageExpr::Column { data, key } => {
                    ExprFacts::Column { rows: data.len(), key: key.clone() }
                }
                StageExpr::Gather { column, positions } => ExprFacts::Gather {
                    column: Box::new(expr_facts(column)),
                    positions: Box::new(expr_facts(positions)),
                },
            }
        }

        let stages = self
            .stages
            .iter()
            .map(|stage| {
                let inputs = stage
                    .inputs
                    .iter()
                    .map(|input| match input {
                        StageInput::Host { data, key } => InputFacts::Host {
                            rows: data.len(),
                            key: Some(key.clone()),
                        },
                        StageInput::Expr(e) => InputFacts::Expr(expr_facts(e)),
                    })
                    .collect();
                match stage.op {
                    StageOp::Select { .. } => StageFacts::select(inputs),
                    StageOp::Join => StageFacts::join(inputs),
                }
            })
            .collect();
        PlanFacts { stages, engines: self.engines }
    }
}

/// Plan→stage lowering state.
struct Lowerer<'a> {
    catalog: &'a Catalog,
    stages: Vec<PipelineStage>,
    metas: Vec<StageMeta>,
}

impl<'a> Lowerer<'a> {
    fn lower(&mut self, plan: &Plan) -> Result<(Finish, VType), PipelineError> {
        match plan {
            Plan::ScanColumn { table, column } => {
                let t = self
                    .catalog
                    .table(table)
                    .ok_or_else(|| PipelineError::UnknownTable(table.clone()))?;
                let c = t.column(column).ok_or_else(|| {
                    PipelineError::UnknownColumn {
                        table: table.clone(),
                        column: column.clone(),
                    }
                })?;
                let vtype = match c.data {
                    ColumnData::U32(_) => VType::ColU32,
                    ColumnData::F32(_) => VType::ColF32,
                };
                Ok((
                    Finish::Base {
                        data: c.data.clone(),
                        key: ColumnKey::new(table.clone(), column.clone()),
                    },
                    vtype,
                ))
            }
            Plan::Select { input, lo, hi } => {
                let (fin, t) = self.lower(input)?;
                require(t, VType::ColU32, "select input")?;
                let input_len = static_len(&fin);
                let stage_input = self.column_stage_input(fin)?;
                let idx = self.stages.len();
                self.stages.push(PipelineStage {
                    op: StageOp::Select { lo: *lo, hi: *hi },
                    inputs: vec![stage_input],
                });
                self.metas.push(StageMeta::Select { input_len });
                Ok((Finish::SelectStage(idx), VType::Cands))
            }
            Plan::Join { left, right } => {
                let (lf, lt) = self.lower(left)?;
                require(lt, VType::ColU32, "join build side")?;
                let (rf, rt) = self.lower(right)?;
                require(rt, VType::ColU32, "join probe side")?;
                let s_len = static_len(&lf);
                let l_len = static_len(&rf);
                let s_input = self.column_stage_input(lf)?;
                let l_input = self.column_stage_input(rf)?;
                let idx = self.stages.len();
                self.stages.push(PipelineStage {
                    op: StageOp::Join,
                    inputs: vec![s_input, l_input],
                });
                self.metas.push(StageMeta::Join { s_len, l_len });
                Ok((Finish::JoinStage(idx), VType::Pairs))
            }
            Plan::JoinSide { join, left_side } => {
                let (fin, t) = self.lower(join)?;
                require(t, VType::Pairs, "join_side input")?;
                let Finish::JoinStage(stage) = fin else {
                    unreachable!("pairs are only produced by join stages");
                };
                Ok((
                    Finish::JoinSide { stage, left: *left_side },
                    VType::Cands,
                ))
            }
            Plan::Project { input, candidates } => {
                let (col_fin, col_t) = self.lower(input)?;
                if col_t != VType::ColU32 && col_t != VType::ColF32 {
                    return Err(PipelineError::TypeMismatch {
                        context: "project input",
                        expected: "column",
                        got: vname(col_t),
                    });
                }
                let (cand_fin, cand_t) = self.lower(candidates)?;
                require(cand_t, VType::Cands, "project candidates")?;
                Ok((
                    Finish::Project {
                        input: Box::new(col_fin),
                        candidates: Box::new(cand_fin),
                    },
                    col_t,
                ))
            }
            Plan::Aggregate { input, kind } => {
                let (fin, t) = self.lower(input)?;
                if t != VType::ColU32 && t != VType::ColF32 {
                    return Err(PipelineError::TypeMismatch {
                        context: "aggregate input",
                        expected: "column",
                        got: vname(t),
                    });
                }
                // Same table the CPU walk validates against
                // (AggKind::expected_input), so error payloads match.
                if let Some(expected) = kind.expected_input() {
                    if expected != vname(t) {
                        return Err(PipelineError::TypeMismatch {
                            context: "aggregate kind",
                            expected,
                            got: vname(t),
                        });
                    }
                }
                Ok((
                    Finish::Aggregate { input: Box::new(fin), kind: *kind },
                    VType::Scalar,
                ))
            }
        }
    }

    /// Turn a u32-column finisher node into a stage input: base columns
    /// ride as host data (with their cache key), anything stage-derived
    /// becomes a dependency expression.
    fn column_stage_input(&self, fin: Finish) -> Result<StageInput, PipelineError> {
        match fin {
            Finish::Base { data: ColumnData::U32(data), key } => {
                Ok(StageInput::Host { data, key })
            }
            other => Ok(StageInput::Expr(self.column_expr(other)?)),
        }
    }

    /// Lower a column-typed finisher node to a dependency expression. A
    /// gather that will run card-side is statically shape-checked (its
    /// source must be as long as the domain its positions index) — an
    /// out-of-range position here would panic deep inside the scheduler,
    /// unlike host-side finisher projects, which keep the CPU executor's
    /// permissive positional semantics.
    fn column_expr(&self, fin: Finish) -> Result<StageExpr, PipelineError> {
        match fin {
            Finish::Base { data: ColumnData::U32(data), key } => {
                Ok(StageExpr::Column { data, key: Some(key) })
            }
            Finish::Base { data: ColumnData::F32(_), .. } => {
                Err(PipelineError::TypeMismatch {
                    context: "offloaded gather source",
                    expected: "u32 column",
                    got: "f32 column",
                })
            }
            Finish::Project { input, candidates } => {
                // Candidate positions index 0..domain, so any source at
                // least as long as the domain is safe; only a *shorter*
                // source is a guaranteed out-of-range gather.
                if let (Some(col_len), Some(dom)) =
                    (static_len(&input), self.domain_len(&candidates))
                {
                    if col_len < dom {
                        return Err(PipelineError::ShapeMismatch {
                            context: "offloaded project",
                            expected: dom,
                            got: col_len,
                        });
                    }
                }
                Ok(StageExpr::Gather {
                    column: Box::new(self.column_expr(*input)?),
                    positions: Box::new(candidates_expr(*candidates)?),
                })
            }
            other => Err(PipelineError::TypeMismatch {
                context: "offloaded stage input",
                expected: "u32 column",
                got: finish_name(&other),
            }),
        }
    }

    /// Static domain length of a candidates-typed finisher node: the
    /// length of the column its positions index, when known.
    fn domain_len(&self, fin: &Finish) -> Option<usize> {
        match fin {
            Finish::SelectStage(i) => match self.metas[*i] {
                StageMeta::Select { input_len } => input_len,
                _ => None,
            },
            Finish::JoinSide { stage, left } => match self.metas[*stage] {
                StageMeta::Join { s_len, l_len } => {
                    if *left {
                        s_len
                    } else {
                        l_len
                    }
                }
                _ => None,
            },
            _ => None,
        }
    }
}

fn require(
    got: VType,
    want: VType,
    context: &'static str,
) -> Result<(), PipelineError> {
    if got == want {
        Ok(())
    } else {
        Err(PipelineError::TypeMismatch {
            context,
            expected: vname(want),
            got: vname(got),
        })
    }
}

/// Length of a column-typed finisher node, when statically known.
fn static_len(fin: &Finish) -> Option<usize> {
    match fin {
        Finish::Base { data, .. } => Some(data.len()),
        _ => None,
    }
}

/// Lower a candidates-typed finisher node to a dependency expression.
fn candidates_expr(fin: Finish) -> Result<StageExpr, PipelineError> {
    match fin {
        Finish::SelectStage(i) => Ok(StageExpr::Candidates(i)),
        Finish::JoinSide { stage, left } => {
            Ok(StageExpr::JoinSide { stage, left })
        }
        other => Err(PipelineError::TypeMismatch {
            context: "offloaded gather positions",
            expected: "candidate list",
            got: finish_name(&other),
        }),
    }
}

fn finish_name(fin: &Finish) -> &'static str {
    match fin {
        Finish::Base { .. } => "base column",
        Finish::SelectStage(_) => "candidate list",
        Finish::JoinStage(_) => "join pairs",
        Finish::JoinSide { .. } => "candidate list",
        Finish::Project { .. } => "projected column",
        Finish::Aggregate { .. } => "scalar",
    }
}

/// Map a stage-index expression to a job-id [`DepExpr`], moving the
/// column payloads (submission hands them to the coordinator).
fn to_dep_expr(expr: StageExpr, ids: &[usize]) -> DepExpr {
    match expr {
        StageExpr::Candidates(i) => DepExpr::Candidates(ids[i]),
        StageExpr::JoinSide { stage, left } => {
            DepExpr::JoinSide { parent: ids[stage], left }
        }
        StageExpr::Column { data, key } => DepExpr::Column { data, key },
        StageExpr::Gather { column, positions } => DepExpr::Gather {
            column: Box::new(to_dep_expr(*column, ids)),
            positions: Box::new(to_dep_expr(*positions, ids)),
        },
    }
}

/// One payload slot of a stage, lowered: either host data (with its
/// cache key) or a dependency edge.
fn lower_input(
    input: StageInput,
    slot: usize,
    ids: &[usize],
    deps: &mut Vec<DepInput>,
) -> (Arc<[u32]>, Option<ColumnKey>) {
    match input {
        StageInput::Host { data, key } => (data, Some(key)),
        StageInput::Expr(e) => {
            deps.push(DepInput { slot, expr: to_dep_expr(e, ids) });
            (Vec::new().into(), None)
        }
    }
}

/// Lock the coordinator, recovering from a poisoned lock: the
/// coordinator holds plain simulator state, so a panic elsewhere cannot
/// leave it logically corrupt. The single recovery point for every
/// holder of the card's coordinator mutex (`udf` reuses it too).
pub(crate) fn lock_coord(
    arc: &Arc<Mutex<Coordinator>>,
) -> std::sync::MutexGuard<'_, Coordinator> {
    match arc.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Lower one stage to a coordinator job spec, wiring dependency edges on
/// the already-submitted parents.
fn stage_to_spec(
    stage: PipelineStage,
    ids: &[usize],
    engines: usize,
    client: usize,
    deadline: Option<f64>,
) -> JobSpec {
    let mut deps: Vec<DepInput> = Vec::new();
    let mut inputs = stage.inputs.into_iter();
    match stage.op {
        StageOp::Select { lo, hi } => {
            let Some(input) = inputs.next() else {
                unreachable!("select stages lower with one input slot")
            };
            let (data, key) = lower_input(input, 0, ids, &mut deps);
            JobSpec::new(JobKind::Selection { data, lo, hi })
                .with_keys(vec![key])
                .with_deps(deps)
                .with_max_engines(engines)
                .with_client(client)
                .with_deadline(deadline)
        }
        StageOp::Join => {
            let (Some(s_input), Some(l_input)) = (inputs.next(), inputs.next())
            else {
                unreachable!("join stages lower with two input slots")
            };
            let (s, s_key) = lower_input(s_input, 0, ids, &mut deps);
            let (l, l_key) = lower_input(l_input, 1, ids, &mut deps);
            // A host build side picks the bitstream variant from its
            // uniqueness (like OffloadRequest); a dependency-fed build
            // side starts conservative and the coordinator re-derives the
            // variant at install time, when the concrete column exists.
            let handle_collisions = if deps.iter().any(|d| d.slot == 0) {
                true
            } else {
                !build_side_is_unique(&s)
            };
            JobSpec::new(JobKind::Join { s, l, handle_collisions })
                .with_keys(vec![s_key, l_key])
                .with_deps(deps)
                .with_max_engines(engines.min(super::request::MAX_JOIN_ENGINES))
                .with_client(client)
                .with_deadline(deadline)
        }
    }
}

/// Evaluate the host-side finisher over the completed stage outputs.
fn eval_finish(fin: &Finish, outs: &BTreeMap<usize, JobOutput>) -> Intermediate {
    match fin {
        Finish::Base { data, .. } => Intermediate::Column(data.clone()),
        Finish::SelectStage(i) => match outs.get(i) {
            Some(JobOutput::Selection(v)) => Intermediate::Candidates(v.clone()),
            other => panic!("stage {i}: expected selection output, got {other:?}"),
        },
        Finish::JoinStage(i) => match outs.get(i) {
            Some(JobOutput::Join(pairs)) => Intermediate::Pairs(pairs.clone()),
            other => panic!("stage {i}: expected join output, got {other:?}"),
        },
        Finish::JoinSide { stage, left } => match outs.get(stage) {
            Some(JobOutput::Join(pairs)) => Intermediate::Candidates(
                pairs
                    .iter()
                    .map(|&(l, r)| if *left { l } else { r })
                    .collect(),
            ),
            other => panic!("stage {stage}: expected join output, got {other:?}"),
        },
        Finish::Project { input, candidates } => {
            let col = eval_finish(input, outs).expect_column();
            let cands = eval_finish(candidates, outs).expect_candidates();
            Intermediate::Column(ops::project(&col, &cands))
        }
        Finish::Aggregate { input, kind } => {
            let col = eval_finish(input, outs).expect_column();
            Intermediate::Scalar(ops::aggregate(&col, *kind))
        }
    }
}

impl FpgaAccelerator {
    /// Submit a whole lowered plan to the card and return immediately.
    /// The DAG is enqueued atomically (one coordinator lock), so several
    /// pipelines — and loose `submit` jobs — co-run under the round
    /// policy. Panics on an invalid request; use
    /// [`try_submit_plan`](FpgaAccelerator::try_submit_plan) to handle
    /// [`PipelineError`] instead.
    pub fn submit_plan(&mut self, request: PipelineRequest) -> PipelineHandle {
        self.try_submit_plan(request)
            .unwrap_or_else(|e| panic!("invalid pipeline request: {e}"))
    }

    /// Non-panicking [`submit_plan`](FpgaAccelerator::submit_plan).
    ///
    /// Before anything reaches the card the request is linted by the
    /// static analyzer ([`crate::analyze`]); a plan with any Error-level
    /// finding — a dependency cycle, a dangling parent, an infeasible
    /// footprint or floorplan — is rejected up front as
    /// [`PipelineError::Rejected`] with the diagnostics, instead of
    /// surfacing later as a runtime
    /// [`CoordinatorError::DependencyStall`] or an engine-placement
    /// abort. Warnings never block submission.
    pub fn try_submit_plan(
        &mut self,
        request: PipelineRequest,
    ) -> Result<PipelineHandle, PipelineError> {
        request.validate()?;
        let card = crate::analyze::CardSpec {
            cfg: self.cfg.clone(),
            link: self.link.clone(),
            default_engines: self.engines,
            ..crate::analyze::CardSpec::default()
        };
        let analysis = crate::analyze::analyze_request(&request, &card);
        if analysis.is_rejected() {
            return Err(PipelineError::Rejected(analysis.error_diagnostics()));
        }
        let PipelineRequest { stages, finish, engines: cap, client, deadline } = request;
        let engines = cap.unwrap_or(self.engines).clamp(1, ENGINE_PORTS);
        // Route the whole DAG as one unit: score the plan's keyed host
        // columns like a single job's inputs and keep every stage on the
        // chosen card, so dependency edges (and pinned intermediates)
        // never cross card boundaries.
        let mut query = RouteQuery::default();
        for stage in &stages {
            for input in &stage.inputs {
                if let StageInput::Host { data, key } = input {
                    let bytes = data.len() as u64 * 4;
                    query.keyed.push((key.clone(), bytes));
                    query.input_bytes += bytes;
                }
            }
        }
        let coord_arc = self.route_plan_arc(&query);
        let mut coord = lock_coord(&coord_arc);
        self.sync_card(&mut coord);
        let mut ids: Vec<usize> = Vec::with_capacity(stages.len());
        for stage in stages {
            let spec = stage_to_spec(stage, &ids, engines, client, deadline);
            match coord.try_submit(spec) {
                Ok(id) => ids.push(id),
                // The graph pass proved every parent is an earlier stage
                // of this very DAG, all submitted just above.
                Err(e) => unreachable!("analyzer admitted an unsound DAG: {e}"),
            }
        }
        drop(coord);
        Ok(PipelineHandle {
            stage_ids: ids,
            finish,
            coord: coord_arc,
            outputs: BTreeMap::new(),
            records: BTreeMap::new(),
            result: None,
            failed: None,
        })
    }
}

/// Aggregate accounting of one completed pipeline, assembled from the
/// per-stage [`JobRecord`]s (each reports its own copy-in — the signal
/// figure drivers compare against the operator-at-a-time path).
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Per-stage records, in stage (submission) order.
    pub stages: Vec<JobRecord>,
}

impl PipelineReport {
    /// Host bytes the whole plan actually moved over the link.
    pub fn copy_in_bytes(&self) -> u64 {
        self.stages.iter().map(|r| r.copy_in_bytes).sum()
    }

    /// Total copy-in time across stages, seconds.
    pub fn copy_in(&self) -> f64 {
        self.stages.iter().map(|r| r.copy_in).sum()
    }

    /// Total engine execution time across stages, seconds.
    pub fn exec(&self) -> f64 {
        self.stages.iter().map(|r| r.exec).sum()
    }

    /// Total copy-out time across stages, seconds.
    pub fn copy_out(&self) -> f64 {
        self.stages.iter().map(|r| r.copy_out).sum()
    }

    /// Per-stage time breakdowns re-derived from a trace stream (enable
    /// tracing with `FpgaAccelerator::set_tracing` before submitting,
    /// drain with `FpgaAccelerator::take_trace`). One entry per stage in
    /// stage order; `None` for a stage with no spans in the stream
    /// (tracing enabled after it ran). Unlike the [`JobRecord`] phase
    /// sums, a [`JobBreakdown`](crate::trace::JobBreakdown) also counts
    /// engine dispatches and waiting time between them — the queueing
    /// view the flat records cannot express.
    pub fn stage_breakdowns(
        &self,
        events: &[crate::trace::Event],
    ) -> Vec<Option<crate::trace::JobBreakdown>> {
        self.stages
            .iter()
            .map(|r| crate::trace::job_breakdown(events, r.id))
            .collect()
    }

    /// End-to-end simulated latency: first submission to last completion
    /// (0 for pipelines with no offload stage).
    pub fn latency(&self) -> f64 {
        let submit = self
            .stages
            .iter()
            .map(|r| r.submit_time)
            .fold(f64::INFINITY, f64::min);
        let finish = self.stages.iter().map(|r| r.finish_time).fold(0.0, f64::max);
        if self.stages.is_empty() {
            0.0
        } else {
            finish - submit
        }
    }
}

/// An in-flight pipeline. Obtained from `FpgaAccelerator::submit_plan`;
/// holds a reference to the card's coordinator, so it stays valid across
/// further submissions and other handles' waits.
///
/// * [`poll`](PipelineHandle::poll) — non-blocking completion check;
///   never advances the card.
/// * [`wait`](PipelineHandle::wait) — drive scheduling rounds until every
///   stage completes, then evaluate the host-side finisher; idempotent.
/// * [`take`](PipelineHandle::take) /
///   [`take_column`](PipelineHandle::take_column) /
///   [`take_candidates`](PipelineHandle::take_candidates) /
///   [`take_pairs`](PipelineHandle::take_pairs) /
///   [`take_scalar`](PipelineHandle::take_scalar) — consuming waits
///   returning the result (typed variants panic on a different root
///   type) plus the per-stage [`PipelineReport`].
///
/// Dropping a handle abandons unclaimed stage *outputs*, not the jobs:
/// stages still run (their cache side effects happen, records survive in
/// `FpgaAccelerator::stats`), and dependent stages of other pipelines are
/// unaffected.
#[must_use = "a PipelineHandle only runs its stages when waited on (or via wait_all)"]
pub struct PipelineHandle {
    stage_ids: Vec<usize>,
    finish: Finish,
    coord: Arc<Mutex<Coordinator>>,
    /// Claimed stage outputs, by stage index.
    outputs: BTreeMap<usize, JobOutput>,
    records: BTreeMap<usize, JobRecord>,
    result: Option<Intermediate>,
    /// First terminal stage failure, cached so repeat waits stay
    /// idempotent on the failure path too.
    failed: Option<CoordinatorError>,
}

impl std::fmt::Debug for PipelineHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PipelineHandle")
            .field("stages", &self.stage_ids.len())
            .field("claimed", &self.outputs.len())
            .field("evaluated", &self.result.is_some())
            .finish()
    }
}

impl PipelineHandle {
    /// Coordinator job ids of the stages, in stage order.
    pub fn ids(&self) -> &[usize] {
        &self.stage_ids
    }

    /// Number of offload stages (0 for pure host plans).
    pub fn stage_count(&self) -> usize {
        self.stage_ids.len()
    }

    fn try_claim(&mut self) {
        let coord = Arc::clone(&self.coord);
        let mut coord = lock_coord(&coord);
        for (si, &id) in self.stage_ids.iter().enumerate() {
            if self.outputs.contains_key(&si) {
                continue;
            }
            if let Some((output, record)) = coord.take_result(id) {
                self.outputs.insert(si, output);
                self.records.insert(si, record);
            }
        }
    }

    fn complete(&self) -> bool {
        self.outputs.len() == self.stage_ids.len()
    }

    /// Has every stage completed? Non-blocking: checks for buffered
    /// results without advancing the simulated card.
    pub fn poll(&mut self) -> bool {
        self.try_claim();
        self.complete()
    }

    /// Drive the card until every stage completed (co-scheduled jobs
    /// from other pipelines progress too), then evaluate the host-side
    /// finisher. Scheduling failures surface as typed errors; a terminal
    /// per-job failure (faulted out, deadline missed, cascaded parent
    /// failure) ends the wait with that stage's error, cached so repeat
    /// waits return it again.
    fn drive_to_completion(&mut self) -> Result<(), CoordinatorError> {
        loop {
            self.try_claim();
            if self.complete() {
                break;
            }
            if let Some(err) = &self.failed {
                return Err(err.clone());
            }
            let coord = Arc::clone(&self.coord);
            let mut coord = lock_coord(&coord);
            for (si, &id) in self.stage_ids.iter().enumerate() {
                if self.outputs.contains_key(&si) {
                    continue;
                }
                if let Some((err, _spec)) = coord.take_failure(id) {
                    drop(coord);
                    self.failed = Some(err.clone());
                    return Err(err);
                }
                assert!(
                    coord.is_in_flight(id),
                    "pipeline stage job {id} vanished without completing"
                );
            }
            coord.step()?;
        }
        if self.result.is_none() {
            self.result = Some(eval_finish(&self.finish, &self.outputs));
        }
        Ok(())
    }

    /// Record the cached terminal failure as a CPU downgrade on the
    /// card's clock — the db executor calls this right before finishing
    /// the plan with CPU operators (graceful degradation).
    pub(crate) fn record_downgrade(&self) {
        if let Some(job) = self.failed.as_ref().and_then(|e| e.failed_job()) {
            lock_coord(&self.coord).record_downgrade(job);
        }
    }

    /// Block until the whole plan completes; returns the root
    /// [`Intermediate`]. Idempotent: repeat calls return the same result.
    /// Panics on a dependency stall — use
    /// [`try_wait`](PipelineHandle::try_wait) to handle
    /// [`CoordinatorError`] instead.
    pub fn wait(&mut self) -> Intermediate {
        self.try_wait()
            .unwrap_or_else(|e| panic!("card cannot make progress: {e}"))
    }

    /// Non-panicking [`wait`](PipelineHandle::wait).
    pub fn try_wait(&mut self) -> Result<Intermediate, CoordinatorError> {
        self.drive_to_completion()?;
        let Some(result) = self.result.clone() else {
            unreachable!("drive_to_completion evaluated the result")
        };
        Ok(result)
    }

    /// Per-stage accounting once every stage completed (`None` before).
    pub fn report(&self) -> Option<PipelineReport> {
        if !self.complete() {
            return None;
        }
        Some(PipelineReport {
            stages: (0..self.stage_ids.len())
                .map(|si| self.records[&si].clone())
                .collect(),
        })
    }

    /// Consuming [`wait`](PipelineHandle::wait): result plus the
    /// per-stage report, without an extra clone of the result.
    pub fn take(mut self) -> (Intermediate, PipelineReport) {
        self.drive_to_completion()
            .unwrap_or_else(|e| panic!("card cannot make progress: {e}"));
        let Some(report) = self.report() else {
            unreachable!("complete pipeline has a report")
        };
        let Some(result) = self.result.take() else {
            unreachable!("drive_to_completion evaluated the result")
        };
        (result, report)
    }

    /// [`take`](PipelineHandle::take), expecting a column root.
    pub fn take_column(self) -> (ColumnData, PipelineReport) {
        let (result, report) = self.take();
        (result.expect_column(), report)
    }

    /// [`take`](PipelineHandle::take), expecting a candidate-list root.
    pub fn take_candidates(self) -> (Vec<u32>, PipelineReport) {
        let (result, report) = self.take();
        (result.expect_candidates(), report)
    }

    /// [`take`](PipelineHandle::take), expecting a join-pairs root.
    pub fn take_pairs(self) -> (Vec<(u32, u32)>, PipelineReport) {
        let (result, report) = self.take();
        (result.expect_pairs(), report)
    }

    /// [`take`](PipelineHandle::take), expecting a scalar root.
    pub fn take_scalar(self) -> (AggResult, PipelineReport) {
        let (result, report) = self.take();
        (result.expect_scalar(), report)
    }
}

impl Drop for PipelineHandle {
    fn drop(&mut self) {
        // Unclaimed stage outputs must not linger in the coordinator's
        // buffer. Ignore a poisoned lock: never panic in drop.
        if let Ok(mut coord) = self.coord.lock() {
            for (si, &id) in self.stage_ids.iter().enumerate() {
                if !self.outputs.contains_key(&si) {
                    coord.abandon(id);
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::db::column::{Column, Table};
    use crate::db::ops::AggKind;
    use crate::hbm::HbmConfig;

    fn catalog() -> Catalog {
        let mut cat = Catalog::new();
        cat.register(Table::new(
            "orders",
            vec![
                Column::u32("okey", (0..64).collect()),
                Column::u32("cust", (0..64).map(|i| i % 8).collect()),
                Column::f32("total", (0..64).map(|i| i as f32).collect()),
            ],
        ));
        cat.register(Table::new(
            "customers",
            vec![Column::u32("ckey", (0..8).collect())],
        ));
        cat
    }

    #[test]
    fn lowering_counts_stages_and_names_them() {
        let cat = catalog();
        let plan = Plan::scan("customers", "ckey")
            .join(
                Plan::scan("orders", "cust")
                    .project(Plan::scan("orders", "okey").select(10, 40)),
            )
            .join_side(false)
            .aggregate(AggKind::Count);
        // Wait: join_side yields candidates; aggregate needs a column.
        let err = PipelineRequest::from_plan(&plan, &cat).unwrap_err();
        assert!(matches!(err, PipelineError::TypeMismatch { .. }));

        let plan = Plan::scan("orders", "okey")
            .project(
                Plan::scan("customers", "ckey")
                    .join(
                        Plan::scan("orders", "cust")
                            .project(Plan::scan("orders", "okey").select(10, 40)),
                    )
                    .join_side(false),
            )
            .aggregate(AggKind::Count);
        // join_side(false) indexes the probe side, whose length is
        // dynamic (a projected column), so the static shape check cannot
        // reject the 64-row gather source — this lowers fine.
        let req = PipelineRequest::from_plan(&plan, &cat).unwrap();
        assert_eq!(req.n_stages(), 2);
        assert_eq!(req.stage_names(), vec!["selection", "join"]);
    }

    #[test]
    fn unknown_names_are_reported() {
        let cat = catalog();
        assert_eq!(
            PipelineRequest::from_plan(&Plan::scan("nope", "x"), &cat).unwrap_err(),
            PipelineError::UnknownTable("nope".into())
        );
        assert_eq!(
            PipelineRequest::from_plan(&Plan::scan("orders", "x"), &cat)
                .unwrap_err(),
            PipelineError::UnknownColumn {
                table: "orders".into(),
                column: "x".into()
            }
        );
    }

    #[test]
    fn type_mismatches_are_rejected() {
        let cat = catalog();
        // Selecting over an f32 column: engines are u32-only.
        let err = PipelineRequest::from_plan(
            &Plan::scan("orders", "total").select(1, 2),
            &cat,
        )
        .unwrap_err();
        assert!(matches!(err, PipelineError::TypeMismatch { .. }), "{err}");
        // Summing a u32 column as f32.
        let err = PipelineRequest::from_plan(
            &Plan::scan("orders", "okey").aggregate(AggKind::SumF32),
            &cat,
        )
        .unwrap_err();
        assert!(matches!(err, PipelineError::TypeMismatch { .. }), "{err}");
        // Joining against an f32 probe side.
        let err = PipelineRequest::from_plan(
            &Plan::scan("orders", "okey").join(Plan::scan("orders", "total")),
            &cat,
        )
        .unwrap_err();
        assert!(matches!(err, PipelineError::TypeMismatch { .. }), "{err}");
    }

    #[test]
    fn static_shape_mismatch_is_rejected_for_stage_fed_gathers() {
        let cat = catalog();
        // Candidates index the 64-row orders domain, but the gather source
        // is the 8-row customers column. Feeding that gather to a select
        // stage would run it card-side, so lowering rejects it…
        let mismatched = Plan::scan("customers", "ckey")
            .project(Plan::scan("orders", "okey").select(0, 10));
        let err =
            PipelineRequest::from_plan(&mismatched.clone().select(0, 5), &cat)
                .unwrap_err();
        assert_eq!(
            err,
            PipelineError::ShapeMismatch {
                context: "offloaded project",
                expected: 64,
                got: 8
            }
        );
        // …while the same project as the host-side *finisher* keeps the
        // CPU executor's positional semantics (it only fails on actually
        // out-of-range positions, identically on both paths).
        assert!(PipelineRequest::from_plan(&mismatched, &cat).is_ok());
    }

    #[test]
    fn engine_cap_is_validated_not_clamped() {
        let cat = catalog();
        let plan = Plan::scan("orders", "okey").select(0, 10);
        let req = PipelineRequest::from_plan(&plan, &cat).unwrap().engines(99);
        assert_eq!(
            req.validate().unwrap_err(),
            PipelineError::EngineCap { requested: 99, limit: ENGINE_PORTS }
        );
        let mut acc = FpgaAccelerator::new(HbmConfig::default());
        let req = PipelineRequest::from_plan(&plan, &cat).unwrap().engines(0);
        assert!(matches!(
            acc.try_submit_plan(req),
            Err(PipelineError::EngineCap { .. })
        ));
        assert_eq!(acc.in_flight(), 0, "rejected pipeline must not enqueue");
    }

    #[test]
    fn traced_pipeline_exposes_stage_breakdowns() {
        let cat = catalog();
        let mut acc = FpgaAccelerator::new(HbmConfig::default());
        acc.set_tracing(true);
        let plan = Plan::scan("orders", "okey")
            .project(
                Plan::scan("customers", "ckey")
                    .join(
                        Plan::scan("orders", "cust")
                            .project(Plan::scan("orders", "okey").select(10, 40)),
                    )
                    .join_side(false),
            )
            .aggregate(AggKind::Count);
        let req = PipelineRequest::from_plan(&plan, &cat).unwrap();
        let handle = acc.submit_plan(req);
        let (_, report) = handle.take();
        let events = acc.take_trace();
        assert!(!events.is_empty(), "tracing on must record the stages");
        let breakdowns = report.stage_breakdowns(&events);
        assert_eq!(breakdowns.len(), report.stages.len());
        for (record, breakdown) in report.stages.iter().zip(&breakdowns) {
            let b = breakdown.expect("traced stage has spans");
            assert!(b.dispatches >= 1);
            // The span-derived execution time is the same accumulation
            // the record keeps, from the same event times.
            assert!(
                (b.running - record.exec).abs() <= 1e-12 + 1e-9 * record.exec,
                "span running {} vs record exec {}",
                b.running,
                record.exec
            );
        }
        // An untraced job id yields None, not a zeroed breakdown.
        assert!(crate::trace::job_breakdown(&events, 10_000).is_none());
    }

    #[test]
    fn faulted_pipeline_releases_intermediate_pins_even_when_abandoned() {
        use crate::fault::{Fault, FaultPlan, ScheduledFault};

        let mut cat = Catalog::new();
        cat.register(Table::new(
            "big",
            vec![
                Column::u32("okey", (0..200_000).collect()),
                Column::u32("cust", (0..200_000).map(|i| i % 1024).collect()),
            ],
        ));
        cat.register(Table::new(
            "dim",
            vec![Column::u32("ckey", (0..1024).collect())],
        ));
        // The join's build side gathers `cust` at the select's output, so
        // stage 1 consumes stage 0's candidates card-side — the pinned
        // transient intermediate whose release this test guards.
        let plan = Plan::scan("big", "cust")
            .project(Plan::scan("big", "okey").select(10_000, 150_000))
            .join(Plan::scan("dim", "ckey"));
        let request = PipelineRequest::from_plan(&plan, &cat).unwrap();
        assert_eq!(request.n_stages(), 2);

        // Fault-free probe: when does the parent stage retire? The
        // simulation is deterministic, so the chaos run below hits the
        // same instant.
        let mut acc = FpgaAccelerator::new(HbmConfig::default());
        let t_parent = {
            let mut h = acc.submit_plan(request.clone());
            h.wait();
            h.report().unwrap().stages[0].finish_time
        };

        // Chaos run: from just after the parent retires, kill every
        // engine port on a 1 µs grid long enough to exhaust the join
        // stage's attempts.
        let t0 = t_parent + 1e-9;
        let mut faults = Vec::new();
        for step in 0..2_000u32 {
            for port in 0..ENGINE_PORTS {
                faults.push(ScheduledFault {
                    at: t0 + f64::from(step) * 1e-6,
                    card: 0,
                    fault: Fault::EngineFault { port },
                });
            }
        }
        let armed = FaultPlan { mix: "custom", seed: 0, cards: 1, faults };
        let mut acc = FpgaAccelerator::new(HbmConfig::default());
        acc.arm_faults(&armed);
        let mut handle = acc.try_submit_plan(request).unwrap();
        let err = handle.try_wait().unwrap_err();
        assert!(
            matches!(
                err,
                CoordinatorError::Faulted { .. }
                    | CoordinatorError::ParentFailed { .. }
            ),
            "{err}"
        );
        let coord = Arc::clone(&handle.coord);
        drop(handle); // abandoned mid-flight, like a client giving up
        assert_eq!(
            lock_coord(&coord).pinned_cache_bytes(),
            0,
            "dead DAG must release its pinned intermediate"
        );
    }

    #[test]
    fn pipeline_deadline_expires_queued_stages_with_a_typed_error() {
        let cat = catalog();
        let mut acc = FpgaAccelerator::new(HbmConfig::default());
        let plan = Plan::scan("orders", "cust")
            .project(Plan::scan("orders", "okey").select(10, 40))
            .join(Plan::scan("customers", "ckey"));
        let request = PipelineRequest::from_plan(&plan, &cat)
            .unwrap()
            .deadline(1e-9);
        let mut handle = acc.try_submit_plan(request).unwrap();
        let child = handle.ids()[1];
        // The select admits at submission time (its budget has not
        // elapsed yet), but the dependent join is still queued when the
        // clock first moves past the budget.
        let err = handle.try_wait().unwrap_err();
        assert_eq!(err, CoordinatorError::DeadlineExceeded { job: child });
        // Idempotent on the failure path, like the success path.
        assert_eq!(
            handle.try_wait().unwrap_err(),
            CoordinatorError::DeadlineExceeded { job: child }
        );
    }

    #[test]
    fn stageless_plan_completes_without_the_card() {
        let cat = catalog();
        let mut acc = FpgaAccelerator::new(HbmConfig::default());
        let req = PipelineRequest::from_plan(
            &Plan::scan("orders", "total").aggregate(AggKind::SumF32),
            &cat,
        )
        .unwrap();
        assert_eq!(req.n_stages(), 0);
        let mut handle = acc.submit_plan(req);
        assert!(handle.poll(), "no stages: complete immediately");
        let (scalar, report) = handle.take_scalar();
        assert_eq!(scalar, AggResult::F64((0..64).map(|i| i as f64).sum()));
        assert!(report.stages.is_empty());
        assert_eq!(report.copy_in_bytes(), 0);
        assert_eq!(acc.stats().completed(), 0, "nothing ran on the card");
    }
}
