//! The FPGA accelerator hook — the UDF-style integration point between
//! the columnar engine and the simulated HBM-FPGA (paper §III, Figure 3).
//!
//! Each offload is end-to-end, exactly as the paper accounts it:
//!
//! 1. **copy-in** — host columns move over OpenCAPI through the two
//!    datamovers into ideally-partitioned HBM placements (one home window
//!    per engine);
//! 2. **execute** — the scale-out engines run under the crossbar fluid
//!    simulation;
//! 3. **copy-out** — padded results return to host memory and are
//!    compacted into the candidate/pair lists the executor consumes.
//!
//! Since the L3 coordinator landed, the accelerator no longer builds a
//! fresh card per offload: it submits a [`JobSpec`] to a private
//! [`Coordinator`] that owns the card for the accelerator's lifetime.
//! That is what makes column residency real — the `*_keyed` entry points
//! carry a `(table, column)` identity, and repeats hit the coordinator's
//! HBM-resident cache and skip copy-in (generalizing the old global
//! `data_resident` flag, which is still honoured as an escape hatch).
//!
//! Submission hands an *owned* copy of the host columns to the job (the
//! coordinator must be able to queue jobs past the borrow), so each
//! offload pays one host-side memcpy of its input on top of the simulated
//! transfers; at figure-driver scale this is noise next to the engines'
//! functional passes.
//!
//! Every offload returns its [`OffloadTiming`] so callers (the figure
//! drivers, the examples) can report rates with or without copies — the
//! distinction Figs. 6 and 8 turn on.

use crate::coordinator::{ColumnKey, Coordinator, JobKind, JobOutput, JobSpec};
use crate::engines::sgd::SgdHyperParams;
use crate::hbm::shim::ENGINE_PORTS;
use crate::hbm::HbmConfig;
use crate::interconnect::opencapi::OpenCapiLink;

/// Timing breakdown of one offload, seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct OffloadTiming {
    pub copy_in: f64,
    pub exec: f64,
    pub copy_out: f64,
}

impl OffloadTiming {
    pub fn total(&self) -> f64 {
        self.copy_in + self.exec + self.copy_out
    }

    pub fn without_copy_in(&self) -> f64 {
        self.exec + self.copy_out
    }
}

/// The simulated HBM-FPGA card as seen by the DBMS.
pub struct FpgaAccelerator {
    pub cfg: HbmConfig,
    pub link: OpenCapiLink,
    /// Engines to use for the next offload (≤ 14 for selection/SGD, ≤ 7
    /// for join).
    pub engines: usize,
    /// Whether input data is already resident in HBM (the paper's
    /// "subsequent queries" case) — skips copy-in accounting. Column-level
    /// residency via the coordinator's cache supersedes this; the flag
    /// remains for whole-card residency experiments.
    pub data_resident: bool,
    coord: Coordinator,
}

impl FpgaAccelerator {
    pub fn new(cfg: HbmConfig) -> Self {
        let coord = Coordinator::new(cfg.clone());
        Self {
            cfg,
            link: OpenCapiLink::default(),
            engines: ENGINE_PORTS,
            data_resident: false,
            coord,
        }
    }

    pub fn with_engines(mut self, engines: usize) -> Self {
        self.engines = engines;
        self
    }

    pub fn resident(mut self) -> Self {
        self.data_resident = true;
        self
    }

    /// The coordinator serving this accelerator (per-job records, cache
    /// hit rates, simulated card time).
    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    fn submit(
        &mut self,
        kind: JobKind,
        keys: Vec<Option<ColumnKey>>,
    ) -> (JobOutput, OffloadTiming) {
        // The public `cfg`/`link` knobs stay live across offloads, exactly
        // as when each offload built a fresh card: sync them into the
        // coordinator before every submission.
        self.coord.set_config(self.cfg.clone());
        self.coord.set_link(self.link.clone());
        let spec = JobSpec::new(kind)
            .with_keys(keys)
            .with_max_engines(self.engines)
            .with_resident(self.data_resident);
        let (output, record) = self.coord.run_single(spec);
        let timing = OffloadTiming {
            copy_in: record.copy_in,
            exec: record.exec,
            copy_out: record.copy_out,
        };
        (output, timing)
    }

    /// Range selection over a host column. Returns (sorted candidate
    /// list, timing).
    pub fn offload_select(&mut self, data: &[u32], lo: u32, hi: u32) -> (Vec<u32>, OffloadTiming) {
        self.offload_select_keyed(None, data, lo, hi)
    }

    /// Range selection with a cache identity: a repeated `(table, column)`
    /// key skips the copy-in while it stays HBM-resident.
    pub fn offload_select_keyed(
        &mut self,
        key: Option<ColumnKey>,
        data: &[u32],
        lo: u32,
        hi: u32,
    ) -> (Vec<u32>, OffloadTiming) {
        let (out, timing) = self.submit(
            JobKind::Selection { data: data.to_vec(), lo, hi },
            vec![key],
        );
        (out.expect_selection(), timing)
    }

    /// Hash join: build side `s`, probe side `l`. Returns
    /// ((s_position, l_index) pairs, timing). `handle_collisions` is
    /// chosen from the data (non-unique S requires it), matching how the
    /// DBMS picks the bitstream variant.
    pub fn offload_join(&mut self, s: &[u32], l: &[u32]) -> (Vec<(u32, u32)>, OffloadTiming) {
        self.offload_join_keyed(None, None, s, l)
    }

    /// Hash join with cache identities for both sides.
    pub fn offload_join_keyed(
        &mut self,
        s_key: Option<ColumnKey>,
        l_key: Option<ColumnKey>,
        s: &[u32],
        l: &[u32],
    ) -> (Vec<(u32, u32)>, OffloadTiming) {
        let mut s_sorted = s.to_vec();
        s_sorted.sort_unstable();
        let s_unique = s_sorted.windows(2).all(|w| w[0] != w[1]);
        self.offload_join_cfg_keyed(s_key, l_key, s, l, !s_unique)
    }

    pub fn offload_join_cfg(
        &mut self,
        s: &[u32],
        l: &[u32],
        handle_collisions: bool,
    ) -> (Vec<(u32, u32)>, OffloadTiming) {
        self.offload_join_cfg_keyed(None, None, s, l, handle_collisions)
    }

    pub fn offload_join_cfg_keyed(
        &mut self,
        s_key: Option<ColumnKey>,
        l_key: Option<ColumnKey>,
        s: &[u32],
        l: &[u32],
        handle_collisions: bool,
    ) -> (Vec<(u32, u32)>, OffloadTiming) {
        let (out, timing) = self.submit(
            JobKind::Join { s: s.to_vec(), l: l.to_vec(), handle_collisions },
            vec![s_key, l_key],
        );
        (out.expect_join(), timing)
    }

    /// Train GLMs on the FPGA: one job per engine slot, replicated data
    /// placement (the paper's high-bandwidth configuration). Returns the
    /// trained models (one per grid entry) and the timing.
    pub fn offload_sgd(
        &mut self,
        features: &[f32],
        labels: &[f32],
        n_features: usize,
        grid: &[SgdHyperParams],
    ) -> (Vec<Vec<f32>>, OffloadTiming) {
        self.offload_sgd_keyed(None, features, labels, n_features, grid)
    }

    /// SGD with a cache identity for the dataset.
    pub fn offload_sgd_keyed(
        &mut self,
        key: Option<ColumnKey>,
        features: &[f32],
        labels: &[f32],
        n_features: usize,
        grid: &[SgdHyperParams],
    ) -> (Vec<Vec<f32>>, OffloadTiming) {
        let (out, timing) = self.submit(
            JobKind::Sgd {
                features: features.to_vec(),
                labels: labels.to_vec(),
                n_features,
                grid: grid.to_vec(),
            },
            vec![key],
        );
        (out.expect_sgd(), timing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu;
    use crate::engines::sgd::GlmTask;
    use crate::hbm::config::FabricClock;
    use crate::workloads::{JoinWorkload, SelectionWorkload};

    fn acc() -> FpgaAccelerator {
        FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200))
    }

    #[test]
    fn offloaded_select_matches_cpu() {
        let w = SelectionWorkload::uniform(200_000, 0.1, 5);
        let (fpga, t) = acc().offload_select(&w.data, w.lo, w.hi);
        let mut cpu = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
        cpu.sort_unstable();
        assert_eq!(fpga, cpu);
        assert!(t.exec > 0.0 && t.copy_in > 0.0 && t.copy_out > 0.0);
    }

    #[test]
    fn resident_data_skips_copy_in() {
        let w = SelectionWorkload::uniform(50_000, 0.0, 6);
        let (_, t) = acc().resident().offload_select(&w.data, w.lo, w.hi);
        assert_eq!(t.copy_in, 0.0);
        // 0% selectivity → no output to copy beyond latency.
        assert!(t.copy_out < 1e-5);
    }

    #[test]
    fn offloaded_join_matches_cpu_positions() {
        let w = JoinWorkload::generate(60_000, 512, true, false, 9);
        let (mut fpga, t) = acc().offload_join(&w.s, &w.l);
        let mut cpu = cpu::join::hash_join_positions(&w.s, &w.l, 4);
        fpga.sort_unstable();
        cpu.sort_unstable();
        assert_eq!(fpga, cpu);
        assert!(t.total() > t.exec);
    }

    #[test]
    fn offloaded_sgd_matches_cpu_trainer() {
        use crate::workloads::datasets::{DatasetSpec, TaskKind};
        let spec = DatasetSpec {
            name: "T",
            samples: 400,
            features: 32,
            task: TaskKind::Regression,
            epochs: 3,
        };
        let d = spec.generate(31);
        let grid = vec![
            SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.05,
                lambda: 0.0,
                minibatch: 16,
                epochs: 3,
            },
            SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.01,
                lambda: 1e-3,
                minibatch: 8,
                epochs: 3,
            },
        ];
        let (models, t) = acc().offload_sgd(&d.features, &d.labels, 32, &grid);
        assert_eq!(models.len(), 2);
        for (params, model) in grid.iter().zip(&models) {
            let (cpu_model, _) =
                cpu::sgd::train(&d.features, &d.labels, 32, params);
            for (a, b) in cpu_model.iter().zip(model) {
                assert!((a - b).abs() < 1e-5);
            }
        }
        assert!(t.exec > 0.0);
    }

    #[test]
    fn keyed_repeat_offload_is_copy_free_on_one_card() {
        let w = SelectionWorkload::uniform(100_000, 0.05, 12);
        let key = ColumnKey::new("lineitem", "qty");
        let mut acc = acc();
        let (r1, t1) =
            acc.offload_select_keyed(Some(key.clone()), &w.data, w.lo, w.hi);
        let (r2, t2) =
            acc.offload_select_keyed(Some(key.clone()), &w.data, w.lo, w.hi);
        assert_eq!(r1, r2);
        assert!(t1.copy_in > 0.0, "first touch pays the copy");
        assert_eq!(t2.copy_in, 0.0, "repeat is HBM-resident");
        assert!((t1.exec - t2.exec).abs() / t1.exec < 1e-9);
        let stats = acc.coordinator().stats();
        assert_eq!(stats.completed(), 2);
        assert_eq!(stats.cache.hits, 1);
    }

    #[test]
    fn accelerator_card_persists_across_offloads() {
        // One card, three different operators back to back — the
        // coordinator must reuse the card without cross-talk.
        let mut acc = acc();
        let w = SelectionWorkload::uniform(60_000, 0.2, 13);
        let (sel, _) = acc.offload_select(&w.data, w.lo, w.hi);
        let jw = JoinWorkload::generate(40_000, 700, true, true, 14);
        let (mut pairs, _) = acc.offload_join(&jw.s, &jw.l);
        let (sel2, _) = acc.offload_select(&w.data, w.lo, w.hi);
        assert_eq!(sel, sel2, "join between selections must not corrupt them");
        let mut cpu_pairs = cpu::join::hash_join_positions(&jw.s, &jw.l, 4);
        pairs.sort_unstable();
        cpu_pairs.sort_unstable();
        assert_eq!(pairs, cpu_pairs);
        assert_eq!(acc.coordinator().stats().completed(), 3);
    }
}
