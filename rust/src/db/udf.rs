//! The FPGA accelerator hook — the UDF-style integration point between
//! the columnar engine and the simulated HBM-FPGA (paper §III, Figure 3).
//!
//! Each offload is end-to-end, exactly as the paper accounts it:
//!
//! 1. **copy-in** — host columns move over OpenCAPI through the two
//!    datamovers into ideally-partitioned HBM placements (one home window
//!    per engine);
//! 2. **execute** — the scale-out engines run under the crossbar fluid
//!    simulation;
//! 3. **copy-out** — padded results return to host memory and are
//!    compacted into the candidate/pair lists the executor consumes.
//!
//! Every offload returns its [`OffloadTiming`] so callers (the figure
//! drivers, the examples) can report rates with or without copies — the
//! distinction Figs. 6 and 8 turn on.

use crate::engines::join::{compact_matches, JoinEngine, JoinJob};
use crate::engines::selection::{compact_results, SelectionEngine, SelectionJob};
use crate::engines::sgd::{SgdEngine, SgdHyperParams, SgdJob};
use crate::engines::{sim, Engine};
use crate::hbm::shim::{Shim, ENGINE_PORTS};
use crate::hbm::{HbmConfig, HbmMemory};
use crate::interconnect::opencapi::OpenCapiLink;

/// Timing breakdown of one offload, seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct OffloadTiming {
    pub copy_in: f64,
    pub exec: f64,
    pub copy_out: f64,
}

impl OffloadTiming {
    pub fn total(&self) -> f64 {
        self.copy_in + self.exec + self.copy_out
    }

    pub fn without_copy_in(&self) -> f64 {
        self.exec + self.copy_out
    }
}

/// The simulated HBM-FPGA card as seen by the DBMS.
pub struct FpgaAccelerator {
    pub cfg: HbmConfig,
    pub link: OpenCapiLink,
    /// Engines to use for the next offload (≤ 14 for selection/SGD, ≤ 7
    /// for join).
    pub engines: usize,
    /// Whether input data is already resident in HBM (the paper's
    /// "subsequent queries" case) — skips copy-in accounting.
    pub data_resident: bool,
}

impl FpgaAccelerator {
    pub fn new(cfg: HbmConfig) -> Self {
        Self { cfg, link: OpenCapiLink::default(), engines: ENGINE_PORTS, data_resident: false }
    }

    pub fn with_engines(mut self, engines: usize) -> Self {
        self.engines = engines;
        self
    }

    pub fn resident(mut self) -> Self {
        self.data_resident = true;
        self
    }

    fn copy_in_time(&self, bytes: u64) -> f64 {
        if self.data_resident {
            0.0
        } else {
            // Two datamovers share the link; a large copy is split between
            // them, so the aggregate rate is the full link bandwidth.
            self.link.transfer_time(bytes, 1)
        }
    }

    /// Range selection over a host column. Returns (sorted candidate
    /// list, timing).
    pub fn offload_select(&mut self, data: &[u32], lo: u32, hi: u32) -> (Vec<u32>, OffloadTiming) {
        let engines = self.engines.min(ENGINE_PORTS).max(1);
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(self.cfg.clone());

        let chunk = data.len().div_ceil(engines);
        let mut jobs = Vec::new();
        for (e, slice) in data.chunks(chunk.max(1)).enumerate() {
            let input = shim
                .alloc(e, (slice.len() * 4) as u64)
                .expect("selection partition exceeds home window");
            // Worst case output = input size (100% selectivity).
            let output = shim
                .alloc(e, (slice.len() * 4) as u64 + 64)
                .expect("selection output exceeds home window");
            input.write_u32s(&mut mem, 0, slice);
            jobs.push(SelectionJob {
                input,
                items: slice.len() as u64,
                index_base: (e * chunk) as u32,
                lo,
                hi,
                output,
            });
        }
        let mut engs: Vec<Box<dyn Engine>> = jobs
            .iter()
            .map(|j| {
                Box::new(SelectionEngine::new(self.cfg.clone(), j.clone()))
                    as Box<dyn Engine>
            })
            .collect();
        let report = sim::run(&self.cfg, &mut mem, &mut engs);

        // Collect per-engine outputs straight from the finished engines
        // (sim borrowed them, so the functional pass ran exactly once).
        let mut result = Vec::new();
        let mut out_bytes_total = 0u64;
        for (j, e) in jobs.iter().zip(&engs) {
            let eng = e
                .as_any()
                .downcast_ref::<SelectionEngine>()
                .expect("selection engine");
            out_bytes_total += eng.out_bytes;
            result.extend(compact_results(&mem, &j.output, eng.out_bytes));
        }
        result.sort_unstable();

        let timing = OffloadTiming {
            copy_in: self.copy_in_time((data.len() * 4) as u64),
            exec: report.makespan,
            copy_out: self.link.transfer_time(out_bytes_total, 1),
        };
        (result, timing)
    }

    /// Hash join: build side `s`, probe side `l`. Returns
    /// ((s_position, l_index) pairs, timing). `handle_collisions` is
    /// chosen from the data (non-unique S requires it), matching how the
    /// DBMS picks the bitstream variant.
    pub fn offload_join(&mut self, s: &[u32], l: &[u32]) -> (Vec<(u32, u32)>, OffloadTiming) {
        let mut s_sorted = s.to_vec();
        s_sorted.sort_unstable();
        let s_unique = s_sorted.windows(2).all(|w| w[0] != w[1]);
        self.offload_join_cfg(s, l, !s_unique)
    }

    pub fn offload_join_cfg(
        &mut self,
        s: &[u32],
        l: &[u32],
        handle_collisions: bool,
    ) -> (Vec<(u32, u32)>, OffloadTiming) {
        // Join engines use two ports each.
        let engines = self.engines.min(ENGINE_PORTS / 2).max(1);
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(self.cfg.clone());

        // S is broadcast: place one copy per engine pair's read port.
        let chunk = l.len().div_ceil(engines);
        let mut jobs = Vec::new();
        for (e, slice) in l.chunks(chunk.max(1)).enumerate() {
            let read_port = e * 2;
            let write_port = e * 2 + 1;
            let s_buf = shim
                .alloc(read_port, (s.len() * 4) as u64 + 64)
                .expect("S exceeds home window");
            s_buf.write_u32s(&mut mem, 0, s);
            let l_buf = shim
                .alloc(read_port, (slice.len() * 4) as u64 + 64)
                .expect("L partition exceeds home window");
            l_buf.write_u32s(&mut mem, 0, slice);
            // Worst-case output sizing: every probe matches ~avg dups.
            let out_cap = (slice.len() as u64 * 16 + 256).min(
                crate::hbm::shim::PORT_HOME_BYTES - 64,
            );
            let output = shim
                .alloc(write_port, out_cap)
                .expect("join output exceeds home window");
            jobs.push(JoinJob {
                s: s_buf,
                s_items: s.len() as u64,
                handle_collisions,
                l: l_buf,
                l_items: slice.len() as u64,
                l_index_base: (e * chunk) as u32,
                output,
            });
        }
        let mut engs: Vec<Box<dyn Engine>> = jobs
            .iter()
            .map(|j| {
                Box::new(JoinEngine::new(self.cfg.clone(), j.clone())) as Box<dyn Engine>
            })
            .collect();
        let report = sim::run(&self.cfg, &mut mem, &mut engs);

        let mut pairs = Vec::new();
        let mut out_bytes_total = 0u64;
        for (j, e) in jobs.iter().zip(&engs) {
            let eng = e.as_any().downcast_ref::<JoinEngine>().expect("join engine");
            out_bytes_total += eng.out_bytes;
            pairs.extend(compact_matches(&mem, &j.output, eng.out_bytes));
        }

        let timing = OffloadTiming {
            copy_in: self.copy_in_time((l.len() * 4 + s.len() * 4) as u64),
            exec: report.makespan,
            copy_out: self.link.transfer_time(out_bytes_total, 1),
        };
        (pairs, timing)
    }

    /// Train GLMs on the FPGA: one job per engine slot, replicated data
    /// placement (the paper's high-bandwidth configuration). Returns the
    /// trained models (one per grid entry) and the timing.
    pub fn offload_sgd(
        &mut self,
        features: &[f32],
        labels: &[f32],
        n_features: usize,
        grid: &[SgdHyperParams],
    ) -> (Vec<Vec<f32>>, OffloadTiming) {
        let engines = self.engines.min(ENGINE_PORTS).max(1);
        let mut all = features.to_vec();
        all.extend_from_slice(labels);
        let bytes = (all.len() * 4) as u64;

        let mut models: Vec<Vec<f32>> = vec![Vec::new(); grid.len()];
        let mut exec_total = 0.0f64;
        // Jobs run in rounds of `engines` (the paper's 28-job search over
        // 14 engines = 2 rounds).
        for (r, round) in grid.chunks(engines).enumerate() {
            let mut mem = HbmMemory::new();
            let mut shim = Shim::new(self.cfg.clone());
            let mut jobs = Vec::new();
            for (e, params) in round.iter().enumerate() {
                let data = shim
                    .alloc(e, bytes)
                    .expect("dataset exceeds home window; use block-wise scan");
                data.write_f32s(&mut mem, 0, &all);
                let model_out = shim.alloc(e, (n_features * 4) as u64 + 64).unwrap();
                jobs.push(SgdJob {
                    data,
                    n_samples: labels.len(),
                    n_features,
                    params: params.clone(),
                    model_out,
                });
            }
            let mut engs: Vec<Box<dyn Engine>> = jobs
                .iter()
                .map(|j| {
                    Box::new(SgdEngine::new(self.cfg.clone(), j.clone()))
                        as Box<dyn Engine>
                })
                .collect();
            let report = sim::run(&self.cfg, &mut mem, &mut engs);
            exec_total += report.makespan;
            // Read the trained models out of the finished engines.
            for (j, e) in engs.iter().enumerate() {
                let eng =
                    e.as_any().downcast_ref::<SgdEngine>().expect("sgd engine");
                models[r * engines + j] = eng.model.clone();
            }
        }

        let timing = OffloadTiming {
            // One copy-in of the dataset (replication inside HBM is an
            // engine-side scatter, charged as one extra HBM pass folded
            // into exec by the sim's write flows).
            copy_in: self.copy_in_time(bytes),
            exec: exec_total,
            copy_out: self
                .link
                .transfer_time((grid.len() * n_features * 4) as u64, 1),
        };
        (models, timing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu;
    use crate::engines::sgd::GlmTask;
    use crate::hbm::config::FabricClock;
    use crate::workloads::{JoinWorkload, SelectionWorkload};

    fn acc() -> FpgaAccelerator {
        FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200))
    }

    #[test]
    fn offloaded_select_matches_cpu() {
        let w = SelectionWorkload::uniform(200_000, 0.1, 5);
        let (fpga, t) = acc().offload_select(&w.data, w.lo, w.hi);
        let mut cpu = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
        cpu.sort_unstable();
        assert_eq!(fpga, cpu);
        assert!(t.exec > 0.0 && t.copy_in > 0.0 && t.copy_out > 0.0);
    }

    #[test]
    fn resident_data_skips_copy_in() {
        let w = SelectionWorkload::uniform(50_000, 0.0, 6);
        let (_, t) = acc().resident().offload_select(&w.data, w.lo, w.hi);
        assert_eq!(t.copy_in, 0.0);
        // 0% selectivity → no output to copy beyond latency.
        assert!(t.copy_out < 1e-5);
    }

    #[test]
    fn offloaded_join_matches_cpu_positions() {
        let w = JoinWorkload::generate(60_000, 512, true, false, 9);
        let (mut fpga, t) = acc().offload_join(&w.s, &w.l);
        let mut cpu = cpu::join::hash_join_positions(&w.s, &w.l, 4);
        fpga.sort_unstable();
        cpu.sort_unstable();
        assert_eq!(fpga, cpu);
        assert!(t.total() > t.exec);
    }

    #[test]
    fn offloaded_sgd_matches_cpu_trainer() {
        use crate::workloads::datasets::{DatasetSpec, TaskKind};
        let spec = DatasetSpec {
            name: "T",
            samples: 400,
            features: 32,
            task: TaskKind::Regression,
            epochs: 3,
        };
        let d = spec.generate(31);
        let grid = vec![
            SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.05,
                lambda: 0.0,
                minibatch: 16,
                epochs: 3,
            },
            SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.01,
                lambda: 1e-3,
                minibatch: 8,
                epochs: 3,
            },
        ];
        let (models, t) = acc().offload_sgd(&d.features, &d.labels, 32, &grid);
        assert_eq!(models.len(), 2);
        for (params, model) in grid.iter().zip(&models) {
            let (cpu_model, _) =
                cpu::sgd::train(&d.features, &d.labels, 32, params);
            for (a, b) in cpu_model.iter().zip(model) {
                assert!((a - b).abs() < 1e-5);
            }
        }
        assert!(t.exec > 0.0);
    }
}
