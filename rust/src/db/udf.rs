//! The FPGA accelerator hook — the UDF-style integration point between
//! the columnar engine and the simulated HBM-FPGA (paper §III, Figure 3).
//!
//! The DBMS↔card boundary is two request/handle pairs:
//!
//! * [`OffloadRequest`] — a typed builder describing one operator
//!   crossing OpenCAPI (payload, engine cap, per-input residency keys);
//!   every validation rule lives there;
//! * [`JobHandle`] — what [`FpgaAccelerator::submit`] returns
//!   *immediately*. Submission only enqueues the job on the card's
//!   coordinator; the simulated card advances when a handle is driven
//!   ([`JobHandle::wait`]) or the accelerator drains
//!   ([`FpgaAccelerator::wait_all`]). [`JobHandle::poll`] never blocks.
//!
//! Whole query plans cross through the sibling pair
//! (`PipelineRequest` → `FpgaAccelerator::submit_plan` →
//! `PipelineHandle`, see [`super::pipeline`]): the plan's operators
//! become a dependency-linked job DAG whose intermediates stay in HBM
//! instead of round-tripping through the host.
//!
//! Because submission and completion are decoupled, a client can keep
//! several operators in flight: the coordinator's continuous event-driven
//! scheduler admits ready jobs the moment engine slots free, so one job's
//! OpenCAPI copy-in overlaps other jobs' compute — the copy/exec
//! trade-off Figs. 6 and 8 turn on — and one client's `wait` makes
//! progress for every in-flight job.
//!
//! Each offload is still accounted end-to-end, exactly as the paper does:
//! **copy-in** over the two datamovers into ideally-partitioned HBM
//! placements, **execute** under the crossbar fluid simulation, and
//! **copy-out** of the padded results, reported per job as
//! [`OffloadTiming`].
//!
//! ## Residency: per-request keys, not a global flag
//!
//! Earlier revisions exposed a whole-card `data_resident` flag (and a
//! `resident()` builder) that skipped all copy-in accounting. That global
//! escape hatch is gone: residency is now declared per request by naming
//! inputs with `(table, column)` keys — `.key("lineitem", "qty")` on the
//! request. The first submission of a key pays the copy-in and leaves the
//! column in the coordinator's HBM-resident LRU cache; subsequent
//! submissions of the same key are copy-free until eviction. To model the
//! paper's "subsequent queries run against resident data" case, submit a
//! keyed warm-up request first and measure the repeat — what a real DBMS
//! does, rather than asserting residency by fiat.
//!
//! Submission hands *shared* (`Arc`-backed) columns to the job: the
//! coordinator holds a handle past the borrow, and no column bytes are
//! copied host-side on submit, publish, or claim. Requests built from
//! plain slices (`OffloadRequest::select(..).on(&data)`) pay exactly one
//! copy into the shared allocation; the plan executor's catalog columns
//! are already shared and cross for free (`on_shared`/`join_shared`).

use std::sync::{Arc, Mutex, MutexGuard};

use super::request::{OffloadRequest, RequestError};
use crate::coordinator::{
    Coordinator, CoordinatorError, CoordinatorStats, JobOutput, JobRecord, Policy,
};
use crate::fleet::{CardView, RouteQuery, Router, RouterKind};
use crate::hbm::shim::ENGINE_PORTS;
use crate::hbm::HbmConfig;
use crate::interconnect::opencapi::OpenCapiLink;

/// Timing breakdown of one offload, seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct OffloadTiming {
    pub copy_in: f64,
    pub exec: f64,
    pub copy_out: f64,
}

impl OffloadTiming {
    pub fn total(&self) -> f64 {
        self.copy_in + self.exec + self.copy_out
    }

    pub fn without_copy_in(&self) -> f64 {
        self.exec + self.copy_out
    }

    fn from_record(record: &JobRecord) -> Self {
        Self {
            copy_in: record.copy_in,
            exec: record.exec,
            copy_out: record.copy_out,
        }
    }
}

/// The simulated HBM-FPGA card as seen by the DBMS.
///
/// One accelerator owns one card for its lifetime (a persistent
/// [`Coordinator`]); every submission goes through
/// [`submit`](FpgaAccelerator::submit) and comes back as a [`JobHandle`].
pub struct FpgaAccelerator {
    /// Card configuration. The card has **one** fabric clock: a change
    /// takes effect at the next [`submit`](FpgaAccelerator::submit) and
    /// applies to the whole card, including jobs still in flight —
    /// co-scheduled engines always share one config, exactly as the
    /// physical card cannot run two clocks at once. Vary the config
    /// between *waits*, not between overlapping submissions, when an
    /// experiment needs per-job clocks.
    pub cfg: HbmConfig,
    /// Host link model; same whole-card semantics as `cfg`.
    pub link: OpenCapiLink,
    /// Default engine cap for requests that don't set `.engines(n)`
    /// (≤ 14 for selection/SGD; joins are further clamped to ≤ 7).
    pub engines: usize,
    coord: Arc<Mutex<Coordinator>>,
    /// Every card of the deployment; `cards[0]` *is* `coord`. One entry
    /// unless [`with_cards`](FpgaAccelerator::with_cards) scaled out.
    cards: Vec<Arc<Mutex<Coordinator>>>,
    /// Routes each submission to a card (trivial on one card).
    router: Router,
    /// Bounded-admission window: most jobs allowed in flight across the
    /// deployment before [`try_submit`](FpgaAccelerator::try_submit)
    /// answers [`RequestError::Overloaded`]. `None` = unbounded (the
    /// closed-loop default).
    admission_bound: Option<usize>,
}

impl FpgaAccelerator {
    pub fn new(cfg: HbmConfig) -> Self {
        // Fair-share by default so in-flight jobs genuinely co-run; a
        // lone job still gets the full engine fleet.
        let coord = Coordinator::new(cfg.clone()).with_policy(Policy::FairShare);
        let coord = Arc::new(Mutex::new(coord));
        Self {
            cfg,
            link: OpenCapiLink::default(),
            engines: ENGINE_PORTS,
            cards: vec![Arc::clone(&coord)],
            coord,
            router: Router::new(RouterKind::Affinity),
            admission_bound: None,
        }
    }

    /// Bound the deployment-wide in-flight window: once `bound` jobs are
    /// queued or running, [`try_submit`](FpgaAccelerator::try_submit)
    /// refuses further work with the typed
    /// [`RequestError::Overloaded`] until completions drain — explicit
    /// backpressure instead of an unbounded card queue. `bound` must be
    /// at least 1.
    pub fn with_admission_bound(mut self, bound: usize) -> Self {
        assert!(bound >= 1, "admission bound must admit at least one job");
        self.admission_bound = Some(bound);
        self
    }

    /// Default engine cap for subsequent requests.
    pub fn with_engines(mut self, engines: usize) -> Self {
        self.engines = engines;
        self
    }

    /// Scale the accelerator out to `cards` simulated cards behind a
    /// fleet `router` ([`crate::fleet`]): every submission — single
    /// offloads and whole plan DAGs alike — is placed on one card by
    /// column-cache affinity (or round-robin), and its handle drives
    /// that card. Call at construction time, before submitting work
    /// (shrinking discards the dropped cards' state).
    pub fn with_cards(mut self, cards: usize, router: RouterKind) -> Self {
        let cards = cards.max(1);
        while self.cards.len() < cards {
            let id = self.cards.len();
            let card = Coordinator::new(self.cfg.clone())
                .with_policy(Policy::FairShare)
                .with_card_id(id);
            self.cards.push(Arc::new(Mutex::new(card)));
        }
        self.cards.truncate(cards);
        self.router = Router::new(router);
        self
    }

    /// Engine-slot policy for co-scheduling in-flight jobs (applied to
    /// every card of the deployment).
    pub fn with_policy(self, policy: Policy) -> Self {
        for card in &self.cards {
            super::pipeline::lock_coord(card).set_policy(policy);
        }
        self
    }

    /// Number of simulated cards behind this accelerator.
    pub fn card_count(&self) -> usize {
        self.cards.len()
    }

    fn coord(&self) -> MutexGuard<'_, Coordinator> {
        super::pipeline::lock_coord(&self.coord)
    }

    /// Sync the public `cfg`/`link` knobs into the coordinator — done
    /// before every submission so the knobs stay live across offloads.
    pub(crate) fn sync_card(&self, coord: &mut Coordinator) {
        coord.set_config(self.cfg.clone());
        coord.set_link(self.link.clone());
    }

    /// Enqueue a request on the card and return immediately. The job only
    /// runs when a [`JobHandle`] is waited on (or polled after someone
    /// else drove the rounds) or [`wait_all`](FpgaAccelerator::wait_all)
    /// drains the queue.
    ///
    /// Panics on an invalid request; use
    /// [`try_submit`](FpgaAccelerator::try_submit) to handle
    /// [`RequestError`] instead.
    pub fn submit(&mut self, request: OffloadRequest) -> JobHandle {
        self.try_submit(request)
            .unwrap_or_else(|e| panic!("invalid offload request: {e}"))
    }

    /// Non-panicking [`submit`](FpgaAccelerator::submit).
    pub fn try_submit(
        &mut self,
        request: OffloadRequest,
    ) -> Result<JobHandle, RequestError> {
        if let Some(bound) = self.admission_bound {
            let in_flight = self.in_flight();
            if in_flight >= bound {
                return Err(RequestError::Overloaded { in_flight, bound });
            }
        }
        let spec = request.into_spec(self.engines)?;
        let card = self.route_query_card(&RouteQuery::from_spec(&spec));
        let arc = Arc::clone(&self.cards[card]);
        let mut coord = super::pipeline::lock_coord(&arc);
        // The public `cfg`/`link` knobs stay live across offloads: sync
        // them into the coordinator before every submission.
        self.sync_card(&mut coord);
        let id = coord.submit(spec);
        drop(coord);
        Ok(JobHandle { id, coord: arc, cached: None, failed: None })
    }

    /// The card a submission lands on: snapshot each card's residency of
    /// the query's keys and its outstanding load under a brief lock, then
    /// ask the router ([`Router::route_query`]). Trivially card 0 on a
    /// single-card deployment.
    fn route_query_card(&mut self, query: &RouteQuery) -> usize {
        if self.cards.len() <= 1 {
            return 0;
        }
        let views: Vec<CardView> = self
            .cards
            .iter()
            .map(|card| {
                let coord = super::pipeline::lock_coord(card);
                CardView {
                    resident_bytes: query
                        .keyed
                        .iter()
                        .filter(|(key, _)| coord.cache().contains(key))
                        .map(|(_, bytes)| *bytes)
                        .sum(),
                    outstanding_bytes: coord.outstanding_input_bytes(),
                }
            })
            .collect();
        self.router.route_query(query, &views)
    }

    /// The card a whole pipeline DAG lands on (used by
    /// [`try_submit_plan`](FpgaAccelerator::try_submit_plan)): the router
    /// scores the plan's keyed host columns exactly like a single job's
    /// inputs, and the entire DAG stays on the chosen card, so dependency
    /// edges never cross card boundaries.
    pub(crate) fn route_plan_arc(
        &mut self,
        query: &RouteQuery,
    ) -> Arc<Mutex<Coordinator>> {
        let card = self.route_query_card(query);
        Arc::clone(&self.cards[card])
    }

    /// Drive the card until every in-flight job has completed. Results
    /// stay claimable through their handles. Panics on a dependency
    /// stall — [`try_wait_all`](FpgaAccelerator::try_wait_all) surfaces
    /// the typed [`CoordinatorError`] instead.
    pub fn wait_all(&mut self) {
        self.try_wait_all()
            .unwrap_or_else(|e| panic!("card cannot make progress: {e}"))
    }

    /// Non-panicking [`wait_all`](FpgaAccelerator::wait_all).
    pub fn try_wait_all(&mut self) -> Result<(), CoordinatorError> {
        for card in &self.cards {
            let mut coord = super::pipeline::lock_coord(card);
            while coord.pending() > 0 {
                coord.step()?;
            }
        }
        Ok(())
    }

    /// Jobs submitted but not yet completed, across every card.
    pub fn in_flight(&self) -> usize {
        self.cards
            .iter()
            .map(|card| super::pipeline::lock_coord(card).pending())
            .sum()
    }

    /// Snapshot of the deployment's accounting: per-job records, cache
    /// hit rates, simulated card time. On one card this is that card's
    /// snapshot; on a fleet the records and cache/byte/busy counters are
    /// summed across cards and `simulated_time` is the *makespan* (each
    /// card keeps its own clock — see [`crate::fleet`]), so busy-seconds
    /// ratios against it are fleet-wide averages. Per-card snapshots come
    /// from [`card_stats`](FpgaAccelerator::card_stats). This clones the
    /// records once (the snapshot must escape the coordinator lock);
    /// drivers that only need summary numbers and hold the `Coordinator`
    /// directly use its borrowed `stats()` view instead.
    pub fn stats(&self) -> CoordinatorStats {
        let mut merged = self.coord().stats().snapshot();
        for card in &self.cards[1..] {
            let s = super::pipeline::lock_coord(card).stats().snapshot();
            merged.records.extend(s.records);
            merged.cache.hits += s.cache.hits;
            merged.cache.misses += s.cache.misses;
            merged.cache.evictions += s.cache.evictions;
            merged.cache.hit_bytes += s.cache.hit_bytes;
            merged.cache.miss_bytes += s.cache.miss_bytes;
            merged.simulated_time = merged.simulated_time.max(s.simulated_time);
            merged.hbm_bytes += s.hbm_bytes;
            merged.host_write_bytes += s.host_write_bytes;
            merged.engine_busy_port_seconds += s.engine_busy_port_seconds;
            merged.link_busy_seconds += s.link_busy_seconds;
            merged.overlap_seconds += s.overlap_seconds;
        }
        merged
    }

    /// One [`CoordinatorStats`] snapshot per card, in card-id order.
    pub fn card_stats(&self) -> Vec<CoordinatorStats> {
        self.cards
            .iter()
            .map(|card| super::pipeline::lock_coord(card).stats().snapshot())
            .collect()
    }

    /// Toggle parallel functional execution on every card's simulator
    /// (on by default). Results are bit-identical either way; only host
    /// wall-clock changes — `hbmctl bench-host` measures the delta.
    pub fn set_parallel_functional(&self, on: bool) {
        for card in &self.cards {
            super::pipeline::lock_coord(card).set_parallel_functional(on);
        }
    }

    /// Toggle the card-clock tracer on every card (off by default — see
    /// `trace` module docs for the zero-overhead contract). Enable
    /// *before* submitting work: the validator rejects streams whose
    /// completed jobs predate the first event.
    pub fn set_tracing(&self, on: bool) {
        for card in &self.cards {
            super::pipeline::lock_coord(card).set_tracing(on);
        }
    }

    /// Drain the trace recorded so far (typed [`crate::trace::Event`]s on
    /// the simulated card clock), leaving the tracer enabled and empty.
    /// Feed the stream to [`crate::trace::chrome_trace`],
    /// [`crate::trace::MetricsRegistry::from_events`], or
    /// [`crate::trace::validate`].
    ///
    /// On a multi-card deployment this drains **card 0 only** — each card
    /// runs its own clock, and interleaving streams would break the
    /// tracer's monotonic-time contract. Use
    /// [`take_card_traces`](FpgaAccelerator::take_card_traces) (one
    /// stream per card, validated per card via
    /// [`crate::trace::validate_cards`]) for fleet traces.
    pub fn take_trace(&self) -> Vec<crate::trace::Event> {
        self.coord().take_trace()
    }

    /// Drain every card's trace, one stream per card in card-id order.
    /// Streams are never merged: per-card clocks are mutually
    /// incomparable (see [`take_trace`](FpgaAccelerator::take_trace)).
    pub fn take_card_traces(&self) -> Vec<Vec<crate::trace::Event>> {
        self.cards
            .iter()
            .map(|card| super::pipeline::lock_coord(card).take_trace())
            .collect()
    }

    /// How the deployment's engine dispatches actually executed their
    /// functional passes: `(parallel, serial)` dispatch counts since the
    /// accelerator was created, summed across cards. This is the ground
    /// truth the static analyzer's parallelism pass predicts: a plan that
    /// lints clean on that pass must not grow the serial count (see
    /// [`crate::analyze`]).
    pub fn functional_dispatches(&self) -> (u64, u64) {
        let mut parallel = 0;
        let mut serial = 0;
        for card in &self.cards {
            let (p, s) = super::pipeline::lock_coord(card).functional_dispatches();
            parallel += p;
            serial += s;
        }
        (parallel, serial)
    }

    /// Arm a deterministic fault schedule on every card. Each card draws
    /// its own share of `plan` (see [`crate::fault`]); an empty plan is a
    /// no-op, leaving the zero-overhead unarmed path intact. Arm *before*
    /// submitting work — faults fire from each card's current clock on.
    pub fn arm_faults(&self, plan: &crate::fault::FaultPlan) {
        if plan.is_empty() {
            return;
        }
        for card in &self.cards {
            super::pipeline::lock_coord(card).arm_faults(plan);
        }
    }

    /// Faults the deployment's cards have injected so far, summed.
    pub fn faults_injected(&self) -> u64 {
        self.cards
            .iter()
            .map(|card| super::pipeline::lock_coord(card).faults_injected())
            .sum()
    }

    /// Fault-aborted attempts that re-entered admission, summed across
    /// cards (terminal failures are not retries).
    pub fn retries(&self) -> u64 {
        self.cards
            .iter()
            .map(|card| super::pipeline::lock_coord(card).retries())
            .sum()
    }

    /// Stages the db executor finished on the CPU after their offload
    /// failed terminally, summed across cards (graceful degradation —
    /// see [`Executor`](super::exec::Executor)).
    pub fn downgrades(&self) -> u64 {
        self.cards
            .iter()
            .map(|card| super::pipeline::lock_coord(card).downgrades())
            .sum()
    }
}

/// An in-flight offload. Obtained from [`FpgaAccelerator::submit`]; holds
/// a reference to the card's coordinator, so it stays valid after further
/// submissions and across other handles' waits.
///
/// * [`poll`](JobHandle::poll) — non-blocking completion check; never
///   advances the card.
/// * [`wait`](JobHandle::wait) — drive scheduling rounds until this job
///   completes; idempotent (repeat calls return a clone of the cached
///   result).
/// * [`take`](JobHandle::take) — consuming `wait`: moves the result out
///   without a clone, for the wait-exactly-once case.
/// * [`wait_selection`](JobHandle::wait_selection) /
///   [`wait_join`](JobHandle::wait_join) /
///   [`wait_sgd`](JobHandle::wait_sgd) — typed conveniences over `take`
///   (consuming, clone-free).
///
/// Dropping a handle abandons the *output*, not the job: the coordinator
/// still runs it (its side effects on the column cache happen) and keeps
/// its [`JobRecord`] in [`FpgaAccelerator::stats`], but the result itself
/// is discarded at completion rather than buffered, so fire-and-forget
/// submission does not accumulate unclaimed outputs.
#[must_use = "a JobHandle only runs its job when waited on (or via wait_all)"]
pub struct JobHandle {
    id: usize,
    coord: Arc<Mutex<Coordinator>>,
    cached: Option<(JobOutput, OffloadTiming)>,
    /// Terminal failure already claimed from the coordinator — kept so
    /// repeated waits stay idempotent on the failure path too.
    failed: Option<CoordinatorError>,
}

impl std::fmt::Debug for JobHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("id", &self.id)
            .field("claimed", &self.cached.is_some())
            .finish()
    }
}

impl JobHandle {
    /// Coordinator job id (matches the `id` of its [`JobRecord`]).
    pub fn id(&self) -> usize {
        self.id
    }

    fn coord(&self) -> MutexGuard<'_, Coordinator> {
        super::pipeline::lock_coord(&self.coord)
    }

    fn try_claim(&mut self) {
        if self.cached.is_none() {
            let taken = self.coord().take_result(self.id);
            if let Some((output, record)) = taken {
                self.cached = Some((output, OffloadTiming::from_record(&record)));
            }
        }
    }

    /// Has the job completed? Non-blocking: checks for a buffered result
    /// without advancing the simulated card, so polling a freshly
    /// submitted job before any round returns `false` immediately.
    pub fn poll(&mut self) -> bool {
        self.try_claim();
        self.cached.is_some()
    }

    /// Drive the card until the job completes (so co-scheduled jobs
    /// progress too), surfacing scheduling failures — and, with a fault
    /// schedule or deadline in play, this job's own *terminal* failure —
    /// as typed errors. A claimed failure is cached so repeated waits
    /// keep returning it instead of tripping the vanished-job assert.
    fn claim_blocking(&mut self) -> Result<(), CoordinatorError> {
        loop {
            self.try_claim();
            if self.cached.is_some() {
                return Ok(());
            }
            if let Some(err) = &self.failed {
                return Err(err.clone());
            }
            let mut coord = self.coord();
            if let Some((err, _spec)) = coord.take_failure(self.id) {
                drop(coord);
                self.failed = Some(err.clone());
                return Err(err);
            }
            assert!(
                coord.is_in_flight(self.id),
                "job {} vanished from the coordinator without completing",
                self.id
            );
            coord.step()?;
        }
    }

    /// Block until the job completes; returns its output and timing.
    /// Idempotent: after completion every call returns the same result
    /// (a clone of the cached output — use [`take`](JobHandle::take) or
    /// a typed `wait_*` for the clone-free single-consumer case).
    /// Panics on a dependency stall — use
    /// [`try_wait`](JobHandle::try_wait) to handle [`CoordinatorError`]
    /// instead.
    pub fn wait(&mut self) -> (JobOutput, OffloadTiming) {
        self.try_wait()
            .unwrap_or_else(|e| panic!("card cannot make progress: {e}"))
    }

    /// Non-panicking [`wait`](JobHandle::wait): the typed scheduler
    /// failure (e.g. [`CoordinatorError::DependencyStall`]) instead of a
    /// process abort.
    pub fn try_wait(&mut self) -> Result<(JobOutput, OffloadTiming), CoordinatorError> {
        self.claim_blocking()?;
        let Some(result) = self.cached.clone() else {
            unreachable!("claim_blocking returned Ok without a claimed result")
        };
        Ok(result)
    }

    /// Consuming [`wait`](JobHandle::wait): blocks until completion and
    /// moves the result out without cloning it.
    pub fn take(mut self) -> (JobOutput, OffloadTiming) {
        self.claim_blocking()
            .unwrap_or_else(|e| panic!("card cannot make progress: {e}"));
        let Some(result) = self.cached.take() else {
            unreachable!("claim_blocking returned Ok without a claimed result")
        };
        result
    }

    /// [`take`](JobHandle::take), expecting a selection's sorted
    /// candidate list. The result is a shared slice — no copy.
    pub fn wait_selection(self) -> (Arc<[u32]>, OffloadTiming) {
        let (output, timing) = self.take();
        (output.expect_selection(), timing)
    }

    /// [`take`](JobHandle::take), expecting a join's `(s_position,
    /// l_index)` pairs.
    pub fn wait_join(self) -> (Arc<[(u32, u32)]>, OffloadTiming) {
        let (output, timing) = self.take();
        (output.expect_join(), timing)
    }

    /// [`take`](JobHandle::take), expecting one trained model per grid
    /// entry, in grid order.
    pub fn wait_sgd(self) -> (Arc<[Vec<f32>]>, OffloadTiming) {
        let (output, timing) = self.take();
        (output.expect_sgd(), timing)
    }

    /// Record the cached terminal failure as a CPU downgrade on the
    /// card's clock — the db executor calls this right before finishing
    /// the stage with CPU operators (graceful degradation).
    pub(crate) fn record_downgrade(&self) {
        if let Some(job) = self.failed.as_ref().and_then(|e| e.failed_job()) {
            self.coord().record_downgrade(job);
        }
    }
}

impl Drop for JobHandle {
    fn drop(&mut self) {
        // An unclaimed result must not linger in the coordinator's buffer
        // forever. Ignore a poisoned lock: never panic in drop.
        if self.cached.is_none() {
            if let Ok(mut coord) = self.coord.lock() {
                coord.abandon(self.id);
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::coordinator::ColumnKey;
    use crate::cpu;
    use crate::db::request::RequestError;
    use crate::engines::sgd::{GlmTask, SgdHyperParams};
    use crate::hbm::config::FabricClock;
    use crate::workloads::{JoinWorkload, SelectionWorkload};

    fn acc() -> FpgaAccelerator {
        FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200))
    }

    #[test]
    fn submitted_select_matches_cpu() {
        let w = SelectionWorkload::uniform(200_000, 0.1, 5);
        let mut acc = acc();
        let (fpga, t) = acc
            .submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
            .wait_selection();
        let mut cpu = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
        cpu.sort_unstable();
        assert_eq!(fpga[..], cpu[..]);
        assert!(t.exec > 0.0 && t.copy_in > 0.0 && t.copy_out > 0.0);
    }

    #[test]
    fn admission_bound_backpressures_with_typed_overloaded() {
        let w = SelectionWorkload::uniform(50_000, 0.1, 5);
        let mut acc = acc().with_admission_bound(2);
        let mut handles = Vec::new();
        for _ in 0..2 {
            handles.push(
                acc.try_submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
                    .expect("window has room"),
            );
        }
        // Third submission hits the bound: typed backpressure, nothing
        // enqueued.
        match acc.try_submit(OffloadRequest::select(w.lo, w.hi).on(&w.data)) {
            Err(RequestError::Overloaded { in_flight, bound }) => {
                assert_eq!((in_flight, bound), (2, 2));
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(acc.in_flight(), 2);
        // Draining completions reopens the window.
        for h in &mut handles {
            h.wait_selection();
        }
        assert!(acc
            .try_submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
            .is_ok());
    }

    #[test]
    fn submitted_join_matches_cpu_positions() {
        let w = JoinWorkload::generate(60_000, 512, true, false, 9);
        let mut acc = acc();
        let (fpga, t) = acc.submit(OffloadRequest::join(&w.s, &w.l)).wait_join();
        let mut fpga = fpga.to_vec();
        let mut cpu = cpu::join::hash_join_positions(&w.s, &w.l, 4);
        fpga.sort_unstable();
        cpu.sort_unstable();
        assert_eq!(fpga, cpu);
        assert!(t.total() > t.exec);
    }

    #[test]
    fn submitted_sgd_matches_cpu_trainer() {
        use crate::workloads::datasets::{DatasetSpec, TaskKind};
        let spec = DatasetSpec {
            name: "T",
            samples: 400,
            features: 32,
            task: TaskKind::Regression,
            epochs: 3,
        };
        let d = spec.generate(31);
        let grid = vec![
            SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.05,
                lambda: 0.0,
                minibatch: 16,
                epochs: 3,
            },
            SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.01,
                lambda: 1e-3,
                minibatch: 8,
                epochs: 3,
            },
        ];
        let mut acc = acc();
        let (models, t) = acc
            .submit(OffloadRequest::sgd(&d.features, &d.labels, 32, &grid))
            .wait_sgd();
        assert_eq!(models.len(), 2);
        for (params, model) in grid.iter().zip(models.iter()) {
            let (cpu_model, _) = cpu::sgd::train(&d.features, &d.labels, 32, params);
            for (a, b) in cpu_model.iter().zip(model) {
                assert!((a - b).abs() < 1e-5);
            }
        }
        assert!(t.exec > 0.0);
    }

    #[test]
    fn keyed_repeat_offload_is_copy_free_on_one_card() {
        let w = SelectionWorkload::uniform(100_000, 0.05, 12);
        let mut acc = acc();
        let req = || OffloadRequest::select(w.lo, w.hi).on(&w.data).key("lineitem", "qty");
        let (r1, t1) = acc.submit(req()).wait_selection();
        let (r2, t2) = acc.submit(req()).wait_selection();
        assert_eq!(r1, r2);
        assert!(t1.copy_in > 0.0, "first touch pays the copy");
        assert_eq!(t2.copy_in, 0.0, "repeat is HBM-resident");
        assert!((t1.exec - t2.exec).abs() / t1.exec < 1e-9);
        let stats = acc.stats();
        assert_eq!(stats.completed(), 2);
        assert_eq!(stats.cache.hits, 1);
    }

    #[test]
    fn accelerator_card_persists_across_offloads() {
        // One card, three different operators back to back — the
        // coordinator must reuse the card without cross-talk.
        let mut acc = acc();
        let w = SelectionWorkload::uniform(60_000, 0.2, 13);
        let sel_req = || OffloadRequest::select(w.lo, w.hi).on(&w.data);
        let (sel, _) = acc.submit(sel_req()).wait_selection();
        let jw = JoinWorkload::generate(40_000, 700, true, true, 14);
        let (pairs, _) = acc.submit(OffloadRequest::join(&jw.s, &jw.l)).wait_join();
        let mut pairs = pairs.to_vec();
        let (sel2, _) = acc.submit(sel_req()).wait_selection();
        assert_eq!(sel, sel2, "join between selections must not corrupt them");
        let mut cpu_pairs = cpu::join::hash_join_positions(&jw.s, &jw.l, 4);
        pairs.sort_unstable();
        cpu_pairs.sort_unstable();
        assert_eq!(pairs, cpu_pairs);
        assert_eq!(acc.stats().completed(), 3);
    }

    #[test]
    fn executor_key_plumbing_reaches_the_cache() {
        let w = SelectionWorkload::uniform(50_000, 0.1, 4);
        let key = Some(ColumnKey::new("t", "v"));
        let mut acc = acc();
        acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data).keyed(key.clone())).take();
        acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data).keyed(key)).take();
        assert_eq!(acc.stats().cache.hits, 1);
    }

    #[test]
    fn try_submit_surfaces_validation_errors() {
        let mut acc = acc();
        let err = acc.try_submit(OffloadRequest::select(0, 1)).unwrap_err();
        assert!(matches!(err, RequestError::MissingData(_)));
        assert_eq!(acc.in_flight(), 0, "rejected request must not enqueue");
    }

    #[test]
    fn multi_card_offloads_route_and_still_match_cpu() {
        let mut acc = acc().with_cards(2, RouterKind::Affinity);
        assert_eq!(acc.card_count(), 2);
        let a = SelectionWorkload::uniform(80_000, 0.1, 21);
        let b = SelectionWorkload::uniform(80_000, 0.1, 22);
        let ha = acc.submit(OffloadRequest::select(a.lo, a.hi).on(&a.data).key("ta", "v"));
        let hb = acc.submit(OffloadRequest::select(b.lo, b.hi).on(&b.data).key("tb", "v"));
        let (ra, _) = ha.wait_selection();
        let (rb, _) = hb.wait_selection();
        for (w, got) in [(&a, &ra), (&b, &rb)] {
            let mut cpu = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
            cpu.sort_unstable();
            assert_eq!(got[..], cpu[..]);
        }
        acc.wait_all();
        assert_eq!(acc.in_flight(), 0);
        let stats = acc.stats();
        assert_eq!(stats.completed(), 2, "merged stats must see both cards' jobs");
        assert_eq!(acc.card_stats().len(), 2);
    }

    #[test]
    fn multi_card_repeat_key_routes_back_to_the_warm_card() {
        let w = SelectionWorkload::uniform(100_000, 0.05, 23);
        let mut acc = acc().with_cards(4, RouterKind::Affinity);
        let req = || OffloadRequest::select(w.lo, w.hi).on(&w.data).key("lineitem", "qty");
        let (r1, t1) = acc.submit(req()).wait_selection();
        let (r2, t2) = acc.submit(req()).wait_selection();
        assert_eq!(r1, r2);
        assert!(t1.copy_in > 0.0, "first touch pays the copy");
        assert_eq!(t2.copy_in, 0.0, "affinity must route the repeat to the warm card");
        assert_eq!(acc.stats().cache.hits, 1);
    }
}
