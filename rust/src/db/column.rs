//! BAT-style columnar storage.
//!
//! MonetDB stores every attribute as a Binary Association Table; the
//! virtual OID is the array position. We keep exactly that: a [`Column`]
//! is a typed dense vector, a [`Table`] a set of equal-length columns, and
//! the [`Catalog`] a name → table map.
//!
//! ## Ownership rule: columns are shared, immutable `Arc` slices
//!
//! [`ColumnData`] wraps `Arc<[u32]>` / `Arc<[f32]>`, and every layer that
//! moves a column — plan lowering, `OffloadRequest` payloads, coordinator
//! job specs, published intermediates, pipeline results — clones the
//! *handle*, never the bytes. Scanning a catalog column, submitting it to
//! the card, and taking it back out are all O(1) in column size. The
//! corollary: column bytes are immutable once constructed; operators that
//! transform data ([`ColumnData::gather`], the CPU operators) allocate a
//! fresh column rather than mutating in place.

use std::collections::BTreeMap;
use std::sync::Arc;

#[derive(Debug, Clone, PartialEq)]
pub enum ColumnData {
    U32(Arc<[u32]>),
    F32(Arc<[f32]>),
}

impl ColumnData {
    /// The element type's name, in the shared error vocabulary of the
    /// executor and the pipeline lowering.
    pub fn type_name(&self) -> &'static str {
        match self {
            ColumnData::U32(_) => "u32 column",
            ColumnData::F32(_) => "f32 column",
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ColumnData::U32(v) => v.len(),
            ColumnData::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_size(&self) -> u64 {
        (self.len() * 4) as u64
    }

    pub fn as_u32(&self) -> Option<&[u32]> {
        match self {
            ColumnData::U32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            ColumnData::F32(v) => Some(v),
            _ => None,
        }
    }

    /// Shared handle on a u32 column — the zero-copy form offload
    /// payloads take (cloning an `Arc`, not the bytes).
    pub fn u32_shared(&self) -> Option<Arc<[u32]>> {
        match self {
            ColumnData::U32(v) => Some(Arc::clone(v)),
            _ => None,
        }
    }

    /// Shared handle on an f32 column.
    pub fn f32_shared(&self) -> Option<Arc<[f32]>> {
        match self {
            ColumnData::F32(v) => Some(Arc::clone(v)),
            _ => None,
        }
    }

    /// Positional gather (late materialization of a candidate list).
    pub fn gather(&self, positions: &[u32]) -> ColumnData {
        match self {
            ColumnData::U32(v) => {
                ColumnData::U32(positions.iter().map(|&p| v[p as usize]).collect())
            }
            ColumnData::F32(v) => {
                ColumnData::F32(positions.iter().map(|&p| v[p as usize]).collect())
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct Column {
    pub name: String,
    pub data: ColumnData,
}

impl Column {
    pub fn u32(name: impl Into<String>, data: Vec<u32>) -> Self {
        Self { name: name.into(), data: ColumnData::U32(data.into()) }
    }

    pub fn f32(name: impl Into<String>, data: Vec<f32>) -> Self {
        Self { name: name.into(), data: ColumnData::F32(data.into()) }
    }
}

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub name: String,
    pub columns: Vec<Column>,
}

impl Table {
    pub fn new(name: impl Into<String>, columns: Vec<Column>) -> Self {
        let t = Self { name: name.into(), columns };
        t.validate();
        t
    }

    fn validate(&self) {
        if let Some(first) = self.columns.first() {
            let n = first.data.len();
            for c in &self.columns {
                assert_eq!(
                    c.data.len(),
                    n,
                    "column '{}' length mismatch in table '{}'",
                    c.name,
                    self.name
                );
            }
        }
    }

    pub fn n_rows(&self) -> usize {
        self.columns.first().map(|c| c.data.len()).unwrap_or(0)
    }

    pub fn column(&self, name: &str) -> Option<&Column> {
        self.columns.iter().find(|c| c.name == name)
    }
}

#[derive(Debug, Default)]
pub struct Catalog {
    tables: BTreeMap<String, Table>,
}

impl Catalog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, table: Table) {
        self.tables.insert(table.name.clone(), table);
    }

    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.tables.keys().map(|s| s.as_str()).collect()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn table_and_catalog_roundtrip() {
        let t = Table::new(
            "lineitem",
            vec![
                Column::u32("key", vec![1, 2, 3]),
                Column::f32("price", vec![9.5, 1.0, 2.5]),
            ],
        );
        assert_eq!(t.n_rows(), 3);
        let mut cat = Catalog::new();
        cat.register(t);
        assert_eq!(cat.names(), vec!["lineitem"]);
        let t = cat.table("lineitem").unwrap();
        assert_eq!(t.column("key").unwrap().data.as_u32().unwrap(), &[1, 2, 3]);
        assert!(t.column("missing").is_none());
    }

    #[test]
    #[should_panic]
    fn ragged_table_rejected() {
        Table::new(
            "bad",
            vec![
                Column::u32("a", vec![1]),
                Column::u32("b", vec![1, 2]),
            ],
        );
    }

    #[test]
    fn gather_materializes_candidates() {
        let d = ColumnData::U32(vec![10, 20, 30, 40].into());
        assert_eq!(d.gather(&[3, 0]), ColumnData::U32(vec![40, 10].into()));
        let f = ColumnData::F32(vec![1.0, 2.0].into());
        assert_eq!(f.gather(&[1]), ColumnData::F32(vec![2.0].into()));
    }

    #[test]
    fn shared_handles_alias_the_same_bytes() {
        let d = ColumnData::U32(vec![1, 2, 3].into());
        let a = d.u32_shared().unwrap();
        let b = d.u32_shared().unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b), "clones share one allocation");
        assert!(d.f32_shared().is_none());
    }
}
