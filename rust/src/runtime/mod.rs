//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! This is the only place the Rust coordinator touches XLA. Python runs
//! once at build time (`make artifacts` → `python/compile/aot.py` →
//! `artifacts/*.hlo.txt` + `manifest.tsv`); at run time this module
//! compiles the HLO text on the PJRT CPU client and executes it — Python
//! is never on the request path.
//!
//! Interchange is HLO *text* because the crate's xla_extension 0.5.1
//! rejects jax ≥ 0.5 serialized protos (64-bit instruction ids); the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactKind, ArtifactMeta, ArtifactRegistry};
pub use client::Runtime;
pub use executor::SgdEpochExecutor;
