//! Artifact registry: the `artifacts/manifest.tsv` index written by
//! `python/compile/aot.py`.
//!
//! TSV, one artifact per line:
//! `name \t file \t kind \t m \t n \t minibatch \t task`
//! (TSV rather than JSON because the offline crate set has no serde).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    SgdEpoch,
    Select,
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub kind: ArtifactKind,
    /// Samples (SGD) or items (select).
    pub m: usize,
    /// Features (SGD); unused for select.
    pub n: usize,
    pub minibatch: usize,
    /// "ridge" | "logistic" | "-".
    pub task: String,
}

#[derive(Debug, Default)]
pub struct ArtifactRegistry {
    artifacts: Vec<ArtifactMeta>,
}

impl ArtifactRegistry {
    /// Load the manifest from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?} (run `make artifacts`)"))?;
        let mut artifacts = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 7 {
                bail!("manifest line {} malformed: {line:?}", ln + 1);
            }
            let kind = match cols[2] {
                "sgd_epoch" => ArtifactKind::SgdEpoch,
                "select" => ArtifactKind::Select,
                other => bail!("unknown artifact kind {other:?}"),
            };
            artifacts.push(ArtifactMeta {
                name: cols[0].to_string(),
                path: dir.join(cols[1]),
                kind,
                m: cols[3].parse().context("m")?,
                n: cols[4].parse().context("n")?,
                minibatch: cols[5].parse().context("minibatch")?,
                task: cols[6].to_string(),
            });
        }
        Ok(Self { artifacts })
    }

    /// Default location: `$HBM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("HBM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn all(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, content: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), content).unwrap();
    }

    #[test]
    fn parses_wellformed_manifest() {
        let dir = std::env::temp_dir().join("hbm_art_test_ok");
        write_manifest(
            &dir,
            "sgd_epoch_tiny_b16\ttiny.hlo.txt\tsgd_epoch\t256\t32\t16\tridge\n\
             select_mask\tsel.hlo.txt\tselect\t65536\t0\t0\t-\n",
        );
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.all().len(), 2);
        let a = reg.get("sgd_epoch_tiny_b16").unwrap();
        assert_eq!(a.kind, ArtifactKind::SgdEpoch);
        assert_eq!((a.m, a.n, a.minibatch), (256, 32, 16));
        assert_eq!(a.task, "ridge");
        assert!(reg.get("nope").is_none());
    }

    #[test]
    fn rejects_malformed_rows() {
        let dir = std::env::temp_dir().join("hbm_art_test_bad");
        write_manifest(&dir, "only\tthree\tcols\n");
        assert!(ArtifactRegistry::load(&dir).is_err());
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let dir = std::env::temp_dir().join("hbm_art_test_missing_xyz");
        let _ = std::fs::remove_dir_all(&dir);
        let err = ArtifactRegistry::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
