//! PJRT client wrapper with a compile cache.
//!
//! One [`Runtime`] per process: a PJRT CPU client plus a name → compiled
//! executable cache, so each artifact is parsed and compiled exactly once
//! no matter how many jobs execute it (compilation is the expensive step;
//! execution is the hot path).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::artifact::{ArtifactMeta, ArtifactRegistry};

pub struct Runtime {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a runtime over the given artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let registry = ArtifactRegistry::load(artifacts_dir)?;
        Ok(Self { client, registry, cache: HashMap::new() })
    }

    /// Default artifacts location (`$HBM_ARTIFACTS` or `./artifacts`).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(&ArtifactRegistry::default_dir())
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn meta(&self, name: &str) -> Result<ArtifactMeta> {
        self.registry
            .get(name)
            .cloned()
            .with_context(|| format!("unknown artifact '{name}'"))
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let meta = self.meta(name)?;
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {:?}", meta.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact on literal inputs; returns the decomposed
    /// output tuple (aot.py lowers with `return_tuple=True`). Inputs are
    /// borrowed — large dataset literals are uploaded by the caller once
    /// and reused across calls.
    pub fn execute(&mut self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing '{name}'"))?;
        let mut out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(out.decompose_tuple()?)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}
