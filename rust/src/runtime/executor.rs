//! Typed executors over the raw runtime: the SGD training step used by
//! the Fig. 11 convergence experiment and the hyperparameter-search
//! example.
//!
//! The executor owns the dataset *literals* (uploaded once) and runs one
//! HLO-compiled epoch per call — the request path is: Rust → PJRT →
//! compiled XLA CPU kernel. No Python anywhere.

use anyhow::{ensure, Context, Result};

use super::client::Runtime;
use crate::engines::sgd::{GlmTask, SgdHyperParams};

/// Executes `sgd_epoch_*` artifacts for a fixed dataset shape.
pub struct SgdEpochExecutor {
    artifact: String,
    pub m: usize,
    pub n: usize,
    pub minibatch: usize,
    pub task: GlmTask,
    features: xla::Literal,
    labels: xla::Literal,
}

impl SgdEpochExecutor {
    /// Build an executor for `artifact`, uploading the dataset once.
    pub fn new(
        rt: &mut Runtime,
        artifact: &str,
        features: &[f32],
        labels: &[f32],
    ) -> Result<Self> {
        let meta = rt.meta(artifact)?;
        ensure!(
            meta.kind == super::artifact::ArtifactKind::SgdEpoch,
            "artifact '{artifact}' is not an sgd_epoch"
        );
        ensure!(
            features.len() == meta.m * meta.n,
            "features: got {} want {}x{}",
            features.len(),
            meta.m,
            meta.n
        );
        ensure!(labels.len() == meta.m, "labels length mismatch");
        let task = match meta.task.as_str() {
            "ridge" => GlmTask::Ridge,
            "logistic" => GlmTask::Logistic,
            other => anyhow::bail!("unknown task '{other}'"),
        };
        // Warm the compile cache now so per-epoch calls are execution-only.
        rt.executable(artifact)?;
        let features = xla::Literal::vec1(features)
            .reshape(&[meta.m as i64, meta.n as i64])
            .context("reshaping features")?;
        let labels = xla::Literal::vec1(labels);
        Ok(Self {
            artifact: artifact.to_string(),
            m: meta.m,
            n: meta.n,
            minibatch: meta.minibatch,
            task,
            features,
            labels,
        })
    }

    /// Run one epoch: model in, updated model out.
    pub fn epoch(&self, rt: &mut Runtime, x: &[f32], alpha: f32, lambda: f32) -> Result<Vec<f32>> {
        ensure!(x.len() == self.n, "model length {} != {}", x.len(), self.n);
        let x_lit = xla::Literal::vec1(x);
        let alpha_lit = xla::Literal::scalar(alpha);
        let lambda_lit = xla::Literal::scalar(lambda);
        // The dataset literals were uploaded once in `new`; only the model
        // vector and two scalars move per epoch.
        let outputs = rt.execute(
            &self.artifact,
            &[&x_lit, &self.features, &self.labels, &alpha_lit, &lambda_lit],
        )?;
        ensure!(outputs.len() == 1, "expected 1-tuple, got {}", outputs.len());
        Ok(outputs[0].to_vec::<f32>()?)
    }

    /// Train for `params.epochs` epochs from zero, returning the model
    /// and the artifact-executed per-epoch models (for loss curves).
    pub fn train(
        &self,
        rt: &mut Runtime,
        params: &SgdHyperParams,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
        ensure!(
            params.minibatch == self.minibatch,
            "artifact is specialized for B={}, asked B={}",
            self.minibatch,
            params.minibatch
        );
        let mut x = vec![0.0f32; self.n];
        let mut history = Vec::with_capacity(params.epochs);
        for _ in 0..params.epochs {
            x = self.epoch(rt, &x, params.alpha, params.lambda)?;
            history.push(x.clone());
        }
        Ok((x, history))
    }
}

