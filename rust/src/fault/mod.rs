//! Deterministic fault injection for the simulated fleet.
//!
//! Wang et al. ("Benchmarking High Bandwidth Memory on FPGAs") show that
//! effective HBM bandwidth is a runtime condition, not a constant — and a
//! production offload path additionally sees transient engine faults and
//! whole-card resets. This module gives the simulator a *seeded* model of
//! exactly those three hazards, scheduled on the simulated card clock:
//!
//! - [`Fault::LinkDegrade`] — the card's OpenCAPI rate is scaled by
//!   `factor` for `window` simulated seconds (the coordinator applies the
//!   factor to whatever link the fleet ingress granted it);
//! - [`Fault::EngineFault`] — the job running on `port` at the fault
//!   event aborts its compute phase and re-enters admission with capped
//!   exponential backoff ([`backoff_delay`]);
//! - [`Fault::CardDown`] — the card rejects new admissions for `window`
//!   seconds and kills its in-flight copy-ins and compute batches (a
//!   *warm* reset: HBM residency and cache accounting survive, results
//!   already crossing back to the host complete).
//!
//! # Determinism contract
//!
//! A [`FaultPlan`] is a pure function of `(mix, seed, cards)`: the same
//! triple always yields the same schedule. Faults *take effect at the
//! first scheduler event at or after* their scheduled time — the card
//! clock is event-driven, so this quantization is what makes an entire
//! chaos run reproducible: same seed → same fault schedule → same event
//! interleaving → same stats, and (via retry/failover/CPU degradation)
//! functional outputs that stay bit-identical to the fault-free run.
//!
//! With no plan armed the scheduler takes none of these paths: the event
//! math of every existing benchmark (`serve`, `plan`, `bench-host`, the
//! Fig. 2 anchors) is untouched.

#![deny(clippy::disallowed_methods)]

use std::collections::VecDeque;

use crate::hbm::shim::ENGINE_PORTS;
use crate::util::rng::Xoshiro256;

/// Attempts a job gets on the card before it fails terminally
/// ([`CoordinatorError::Faulted`](crate::coordinator::CoordinatorError))
/// and the layer above must rescue it: the fleet by re-routing the spec
/// to another card, the [`Executor`](crate::db::Executor) by finishing
/// the stage on the CPU path.
pub const MAX_ATTEMPTS: u32 = 3;

/// First retry delay, in simulated card seconds.
pub const BACKOFF_BASE: f64 = 20e-6;

/// Ceiling on the exponential backoff, in simulated card seconds.
pub const BACKOFF_CAP: f64 = 320e-6;

/// Capped exponential backoff before attempt `attempts + 1`, in card
/// seconds: `BACKOFF_BASE × 2^(attempts-1)`, clamped to [`BACKOFF_CAP`].
pub fn backoff_delay(attempts: u32) -> f64 {
    let exp = attempts.saturating_sub(1).min(16);
    (BACKOFF_BASE * f64::from(1u32 << exp)).min(BACKOFF_CAP)
}

/// One typed fault, as it lands on a card.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Scale the card's host-link rate by `factor` for `window` seconds.
    LinkDegrade { factor: f64, window: f64 },
    /// Abort the compute batch running on `port` (no-op if the port is
    /// idle at the fault event).
    EngineFault { port: usize },
    /// Reject admissions for `window` seconds and kill in-flight work.
    CardDown { window: f64 },
}

impl Fault {
    /// Short label for trace events and JSON reports.
    pub fn name(&self) -> &'static str {
        match self {
            Fault::LinkDegrade { .. } => "link-degrade",
            Fault::EngineFault { .. } => "engine-fault",
            Fault::CardDown { .. } => "card-down",
        }
    }
}

/// A fault pinned to a card and a card-clock time.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledFault {
    /// Card-clock seconds; the fault fires at the first scheduler event
    /// at or after this time.
    pub at: f64,
    /// Fleet card the fault lands on (0 for a lone coordinator).
    pub card: usize,
    pub fault: Fault,
}

/// A seeded, fleet-wide fault schedule — the single source every armed
/// card filters its own share from ([`ArmedFaults::new`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Mix name this plan was generated from (`none`, `standard`,
    /// `heavy`).
    pub mix: &'static str,
    pub seed: u64,
    pub cards: usize,
    /// Time-ordered schedule (ties keep generation order).
    pub faults: Vec<ScheduledFault>,
}

impl FaultPlan {
    /// The empty plan: arming it is indistinguishable from not arming
    /// anything.
    pub fn none() -> Self {
        FaultPlan { mix: "none", seed: 0, cards: 0, faults: Vec::new() }
    }

    /// Whether this plan schedules any fault at all.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Number of scheduled [`Fault::CardDown`] events.
    pub fn card_down_events(&self) -> usize {
        self.faults
            .iter()
            .filter(|f| matches!(f.fault, Fault::CardDown { .. }))
            .count()
    }

    /// Resolve a named mix into a concrete seeded plan. Valid names:
    /// `none`, `standard` (the acceptance mix: engine faults + link
    /// degradation + two card outages), `heavy` (dense engine faults
    /// that exhaust [`MAX_ATTEMPTS`] and force CPU downgrades). Returns
    /// the unknown name on failure so CLI errors can echo it.
    pub fn parse_mix(name: &str, seed: u64, cards: usize) -> Result<Self, String> {
        match name {
            "none" => Ok(Self::none()),
            "standard" => Ok(Self::standard(seed, cards)),
            "heavy" => Ok(Self::heavy(seed, cards)),
            other => Err(other.to_string()),
        }
    }

    /// The standard chaos mix: per card, periodic engine faults with
    /// seeded port/jitter draws and occasional link-degrade windows;
    /// fleet-wide, two card outages. Dense from t = 0 so any workload
    /// long enough to schedule at all takes hits; events past the
    /// workload's makespan simply never fire.
    pub fn standard(seed: u64, cards: usize) -> Self {
        let cards = cards.max(1);
        let mut rng = Xoshiro256::new(seed ^ 0xFA17);
        let mut faults = Vec::new();
        for card in 0..cards {
            // Engine faults: one every ~150 µs for 30 ms of card time.
            for k in 0..200u32 {
                let jitter = 30e-6 * rng.next_f64();
                faults.push(ScheduledFault {
                    at: f64::from(k) * 150e-6 + jitter,
                    card,
                    fault: Fault::EngineFault {
                        port: rng.next_u32() as usize % ENGINE_PORTS,
                    },
                });
            }
            // Link degradation: ~300 µs windows at 30–70% rate.
            for k in 0..40u32 {
                let jitter = 100e-6 * rng.next_f64();
                faults.push(ScheduledFault {
                    at: f64::from(k) * 750e-6 + jitter,
                    card,
                    fault: Fault::LinkDegrade {
                        factor: 0.3 + 0.4 * rng.next_f64(),
                        window: 300e-6,
                    },
                });
            }
        }
        // Two whole-card outages on seeded cards (a lone card takes both
        // and rides them out on local retry after the window). The first
        // lands ~30–50 µs in — a queued copy-in alone takes longer, so
        // any multi-card replay that schedules at all still holds work on
        // the down card and must exercise failover.
        for k in 0..2u32 {
            faults.push(ScheduledFault {
                at: 30e-6 + f64::from(k) * 1.7e-3 + 20e-6 * rng.next_f64(),
                card: rng.next_u32() as usize % cards,
                fault: Fault::CardDown { window: 400e-6 },
            });
        }
        sort_by_time(&mut faults);
        FaultPlan { mix: "standard", seed, cards, faults }
    }

    /// The heavy mix: engine faults every ~20 µs sweeping all ports, so
    /// any non-trivial job is hit more than [`MAX_ATTEMPTS`] times and
    /// fails terminally — the mix that exercises fleet re-routing of
    /// failed specs and the [`Executor`](crate::db::Executor) CPU
    /// degradation ladder.
    pub fn heavy(seed: u64, cards: usize) -> Self {
        let cards = cards.max(1);
        let mut rng = Xoshiro256::new(seed ^ 0x0EA5F);
        let mut faults = Vec::new();
        for card in 0..cards {
            for k in 0..1500u32 {
                let jitter = 8e-6 * rng.next_f64();
                faults.push(ScheduledFault {
                    at: f64::from(k) * 20e-6 + jitter,
                    card,
                    fault: Fault::EngineFault {
                        port: (k as usize * 5 + rng.next_u32() as usize)
                            % ENGINE_PORTS,
                    },
                });
            }
        }
        sort_by_time(&mut faults);
        FaultPlan { mix: "heavy", seed, cards, faults }
    }
}

fn sort_by_time(faults: &mut [ScheduledFault]) {
    faults.sort_by(|a, b| a.at.total_cmp(&b.at));
}

/// One card's armed share of a [`FaultPlan`], plus the card-local fault
/// state the scheduler consults at every event: the still-pending
/// schedule, the active degrade/down windows, and the injection counter.
#[derive(Debug, Clone)]
pub struct ArmedFaults {
    /// This card's faults, time-ordered, still to fire.
    schedule: VecDeque<(f64, Fault)>,
    /// Active link-degrade window: `(until, factor)`.
    degrade: Option<(f64, f64)>,
    /// End of the active down window, if the card is down.
    down_until: Option<f64>,
    /// The card's undegraded link rate, captured at arm time
    /// ([`Card::inject`](crate::coordinator::Card::inject)). A degrade
    /// caps the effective rate at `nominal_link × factor` even when a
    /// fleet ingress grant rebinds the card's link between events.
    nominal_link: f64,
    /// Faults that actually fired so far.
    pub injected: u64,
}

impl ArmedFaults {
    /// Filter `plan` down to `card`'s schedule.
    pub fn new(plan: &FaultPlan, card: usize) -> Self {
        ArmedFaults {
            schedule: plan
                .faults
                .iter()
                .filter(|f| f.card == card)
                .map(|f| (f.at, f.fault.clone()))
                .collect(),
            degrade: None,
            down_until: None,
            nominal_link: f64::INFINITY,
            injected: 0,
        }
    }

    /// Record the card's undegraded link rate (called once at arm time).
    pub fn set_nominal_link(&mut self, bytes_per_sec: f64) {
        self.nominal_link = bytes_per_sec;
    }

    /// Ceiling a degrade puts on the card's effective link rate at
    /// `now`: `nominal × factor` inside a window, `+∞` otherwise. The
    /// scheduler applies `min(granted, degrade_cap)` so a fleet's
    /// ingress share and an injected degrade compose without double
    /// scaling.
    pub fn degrade_cap(&mut self, now: f64) -> f64 {
        let factor = self.link_factor(now);
        if factor < 1.0 {
            self.nominal_link * factor
        } else {
            f64::INFINITY
        }
    }

    /// Pop the next fault scheduled at or before `now` (quantization to
    /// the current event), counting it as injected.
    pub fn pop_due(&mut self, now: f64) -> Option<Fault> {
        let due = self.schedule.front().is_some_and(|&(at, _)| at <= now);
        if !due {
            return None;
        }
        self.injected += 1;
        self.schedule.pop_front().map(|(_, f)| f)
    }

    /// Open a link-degrade window ending at `now + window`. Overlapping
    /// windows keep the later end and the newer factor.
    pub fn open_degrade(&mut self, now: f64, factor: f64, window: f64) {
        let until = now + window;
        let end = match self.degrade {
            Some((prev, _)) => prev.max(until),
            None => until,
        };
        self.degrade = Some((end, factor));
    }

    /// Open a down window ending at `now + window` (later end wins).
    pub fn open_down(&mut self, now: f64, window: f64) {
        let until = now + window;
        self.down_until =
            Some(self.down_until.map_or(until, |prev| prev.max(until)));
    }

    /// The card's current link scale: degrade factor inside an active
    /// window, 1.0 otherwise (expired windows are dropped here).
    pub fn link_factor(&mut self, now: f64) -> f64 {
        match self.degrade {
            Some((until, factor)) if now < until => factor,
            Some(_) => {
                self.degrade = None;
                1.0
            }
            None => 1.0,
        }
    }

    /// Whether the card rejects admissions at `now` (expired windows are
    /// dropped here).
    pub fn is_down(&mut self, now: f64) -> bool {
        match self.down_until {
            Some(until) if now < until => true,
            Some(_) => {
                self.down_until = None;
                false
            }
            None => false,
        }
    }

    /// End of the active down window, if any.
    pub fn down_until(&self) -> Option<f64> {
        self.down_until
    }

    /// Earliest time anything armed here changes state on an idle card:
    /// the next scheduled fault, a window expiry — the fast-forward
    /// target when the session has nothing else to do.
    pub fn next_change(&self) -> Option<f64> {
        let mut t: Option<f64> = self.schedule.front().map(|&(at, _)| at);
        for cand in
            [self.degrade.map(|(until, _)| until), self.down_until].into_iter().flatten()
        {
            t = Some(t.map_or(cand, |cur| cur.min(cand)));
        }
        t
    }

    /// Nothing left: no fault still scheduled, no window still open.
    pub fn exhausted(&self) -> bool {
        self.schedule.is_empty() && self.degrade.is_none() && self.down_until.is_none()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_pure_functions_of_mix_seed_and_cards() {
        for mix in ["none", "standard", "heavy"] {
            let a = FaultPlan::parse_mix(mix, 7, 4).unwrap();
            let b = FaultPlan::parse_mix(mix, 7, 4).unwrap();
            assert_eq!(a, b, "{mix}: same triple must reproduce the schedule");
            if mix != "none" {
                let c = FaultPlan::parse_mix(mix, 8, 4).unwrap();
                assert_ne!(a, c, "{mix}: a different seed must move the schedule");
            }
        }
        assert!(FaultPlan::parse_mix("bogus", 7, 4).is_err());
    }

    #[test]
    fn standard_mix_covers_every_fault_kind_and_is_time_ordered() {
        let plan = FaultPlan::standard(7, 4);
        assert!(plan.faults.windows(2).all(|w| w[0].at <= w[1].at));
        assert!(plan.faults.iter().all(|f| f.card < 4 && f.at >= 0.0));
        for name in ["engine-fault", "link-degrade", "card-down"] {
            assert!(
                plan.faults.iter().any(|f| f.fault.name() == name),
                "standard mix must schedule {name}"
            );
        }
        assert_eq!(plan.card_down_events(), 2);
        for f in &plan.faults {
            match &f.fault {
                Fault::EngineFault { port } => assert!(*port < ENGINE_PORTS),
                Fault::LinkDegrade { factor, window } => {
                    assert!(*factor > 0.0 && *factor < 1.0 && *window > 0.0);
                }
                Fault::CardDown { window } => assert!(*window > 0.0),
            }
        }
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        assert_eq!(backoff_delay(1), BACKOFF_BASE);
        assert_eq!(backoff_delay(2), 2.0 * BACKOFF_BASE);
        assert_eq!(backoff_delay(3), 4.0 * BACKOFF_BASE);
        assert_eq!(backoff_delay(30), BACKOFF_CAP);
        assert!(backoff_delay(0) <= BACKOFF_BASE);
    }

    #[test]
    fn armed_faults_quantize_windows_on_the_card_clock() {
        let plan = FaultPlan {
            mix: "standard",
            seed: 0,
            cards: 2,
            faults: vec![
                ScheduledFault {
                    at: 1e-3,
                    card: 0,
                    fault: Fault::LinkDegrade { factor: 0.5, window: 1e-3 },
                },
                ScheduledFault {
                    at: 5e-3,
                    card: 1,
                    fault: Fault::CardDown { window: 2e-3 },
                },
            ],
        };
        let mut armed = ArmedFaults::new(&plan, 0);
        assert!(armed.pop_due(0.5e-3).is_none(), "nothing due yet");
        assert_eq!(armed.next_change(), Some(1e-3));
        // Quantized: the event at 1.4 ms picks up the 1 ms fault.
        let Some(Fault::LinkDegrade { factor, window }) = armed.pop_due(1.4e-3)
        else {
            panic!("due fault must pop");
        };
        armed.open_degrade(1.4e-3, factor, window);
        assert_eq!(armed.link_factor(2.0e-3), 0.5);
        assert_eq!(armed.link_factor(2.5e-3), 1.0, "window expired");
        assert_eq!(armed.injected, 1);
        assert!(armed.exhausted(), "card 1's fault is not card 0's");

        let mut other = ArmedFaults::new(&plan, 1);
        let Some(Fault::CardDown { window }) = other.pop_due(5e-3) else {
            panic!("card 1 must see its outage");
        };
        other.open_down(5e-3, window);
        assert!(other.is_down(6e-3));
        assert_eq!(other.down_until(), Some(7e-3));
        assert!(!other.is_down(7.1e-3));
        assert!(other.exhausted());
    }
}
