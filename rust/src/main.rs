//! `hbmctl` — the Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   figures     regenerate paper tables/figures (`--fig fig2|table1|all`)
//!   microbench  HBM bandwidth/latency microbenchmarks (§II)
//!   resources   Table III resource/floorplan report
//!   train       train a GLM through the PJRT runtime (HLO artifacts)
//!   query       demo DB query, CPU vs FPGA-offloaded
//!   plan        whole-plan pipelines vs operator-at-a-time offload
//!   check       static plan analysis (lint a workload, no execution)
//!   serve       multi-client mixed workload through the L3 coordinator
//!   sweep       open-loop client ladder: bounded admission, load shedding,
//!               SLO-aware scheduling under overload
//!   chaos       seeded fault injection over the fleet: retry, failover,
//!               deadlines, graceful CPU degradation
//!   trace       card-clock trace of the analytics mix + validation matrix
//!   bench-host  simulator wall-clock throughput: serial vs parallel,
//!               cold vs physically-resident
//!   help        full usage with per-subcommand options
//!
//! Examples:
//!   hbmctl figures --fig all --scale 0.0625 --out results
//!   hbmctl microbench --ports 32 --separations 256,128,0
//!   hbmctl train --dataset tiny_ridge --alpha 0.05 --epochs 10
//!   hbmctl plan --rows 200000 --repeat 2
//!   hbmctl check --rows 200000
//!   hbmctl check --fixture broken
//!   hbmctl serve --clients 4 --queries 64 --policy all
//!   hbmctl chaos --cards 4 --seed 7 --faults standard
//!   hbmctl trace --rows 100000 --repeat 2
//!   hbmctl bench-host --rows 400000

// The binary is driver code outside the scheduler-layer no-unwrap scope
// (see clippy.toml); `anyhow` errors are the contract here.
#![allow(clippy::disallowed_methods)]

use std::path::PathBuf;
use std::process::ExitCode;

use hbm_analytics::bench::figures::{self, FigureCtx};
use hbm_analytics::coordinator::{self, Policy, ServeSpec};
use hbm_analytics::db::{Catalog, Column, Executor, FpgaAccelerator, Plan, Table};
use hbm_analytics::engines::sgd::{GlmTask, SgdHyperParams};
use hbm_analytics::fleet::RouterKind;
use hbm_analytics::hbm::shim::ENGINE_PORTS;
use hbm_analytics::hbm::{fig2_sweep, FabricClock, HbmConfig};
use hbm_analytics::runtime::{Runtime, SgdEpochExecutor};
use hbm_analytics::serve_front;
use hbm_analytics::util::cli::Args;
use hbm_analytics::util::units::MIB;
use hbm_analytics::workloads::datasets::{DatasetSpec, TaskKind};

fn main() -> ExitCode {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("figures") => cmd_figures(&args),
        Some("microbench") => cmd_microbench(&args),
        Some("resources") => cmd_resources(&args),
        Some("train") => cmd_train(&args),
        Some("query") => cmd_query(&args),
        Some("plan") => cmd_plan(&args),
        Some("check") => cmd_check(&args),
        Some("serve") => cmd_serve(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("trace") => cmd_trace(&args),
        Some("bench-host") => cmd_bench_host(&args),
        Some("help") => {
            usage();
            return ExitCode::SUCCESS;
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            eprintln!("{}", subcommand_list());
            return ExitCode::FAILURE;
        }
        None => {
            usage();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// The full subcommand roster with one-line descriptions — what an
/// unknown subcommand gets (run `hbmctl help` for per-subcommand
/// options).
fn subcommand_list() -> &'static str {
    "subcommands:\n\
     \u{20} figures     regenerate paper tables/figures (--fig fig2|table1|all)\n\
     \u{20} microbench  HBM bandwidth/latency microbenchmarks (paper §II)\n\
     \u{20} resources   Table III resource/floorplan report\n\
     \u{20} train       train a GLM through the PJRT runtime (HLO artifacts)\n\
     \u{20} query       demo DB query, CPU vs FPGA-offloaded\n\
     \u{20} plan        whole-plan pipelines vs operator-at-a-time offload\n\
     \u{20} check       static plan analysis: lint a workload without executing it\n\
     \u{20} serve       multi-client mixed workload through the L3 coordinator\n\
     \u{20} sweep       open-loop client ladder: bounded admission, load\n\
     \u{20}             shedding, SLO-aware scheduling under overload\n\
     \u{20} chaos       seeded fault injection over the fleet: retry, failover,\n\
     \u{20}             deadlines, graceful CPU degradation\n\
     \u{20} trace       card-clock trace of the analytics mix (Perfetto JSON)\n\
     \u{20}             plus the trace-vs-stats validation matrix\n\
     \u{20} bench-host  simulator wall-clock throughput benchmark\n\
     \u{20} help        full usage with per-subcommand options"
}

fn usage() {
    eprintln!(
        "usage: hbmctl <figures|microbench|resources|train|query|plan|check|serve|sweep|chaos|trace|bench-host|help> [options]\n\
         \n\
         figures    --fig <id|all> --scale <f> --out <dir> --artifacts <dir>\n\
         microbench --ports <list> --separations <list> --clock <200|300|400>\n\
         resources  (no options)\n\
         train      --dataset <tiny_ridge|tiny_logistic|im|mnist|aea|syn>\n\
         \u{20}          --alpha <f> --lambda <f> --epochs <n> --minibatch <1|4|16>\n\
         query      --rows <n> --offload <true|false>\n\
         \u{20}          --engines <1..14>   compute engines granted to each offload\n\
         \u{20}          --repeat <n>        run the plan n times on one card; repeats\n\
         \u{20}          hit the HBM-resident column cache and skip copy-in\n\
         plan       --rows <n> --repeat <r> --seed <s> --out <file.json>\n\
         \u{20}          runs a mixed-plan workload as whole-query pipelines\n\
         \u{20}          (submit_plan) vs operator-at-a-time offloads, verifies\n\
         \u{20}          identical results, and writes BENCH_pipeline.json with\n\
         \u{20}          the moved-bytes savings and the analyzer's predicted\n\
         \u{20}          copy-in bytes next to the measured total\n\
         check      --rows <n> --seed <s> --fixture <analytics|broken> --out <file.json>\n\
         \u{20}          --cards <n> --partitioner <hash|range>\n\
         \u{20}          with --cards > 1, lints each plan against the fleet\n\
         \u{20}          card the cold router would choose (the route diagnostic\n\
         \u{20}          names the card id)\n\
         \u{20}          runs the five static-analysis passes (graph, capacity,\n\
         \u{20}          parallelism, floorplan, cost bounds) over the analytics\n\
         \u{20}          plan mix — or the intentionally broken fixture — without\n\
         \u{20}          executing anything, prints every diagnostic, and writes\n\
         \u{20}          CHECK_report.json\n\
         serve      --clients <n> --queries <m> --policy <fifo|fair|bandwidth|all>\n\
         \u{20}          --rows <n> --seed <s> --cache-mib <n> --out <file.json>\n\
         \u{20}          --cards <n> --router <affinity|round-robin> --host-gbs <f>\n\
         \u{20}          replays a mixed selection/join/SGD workload through the\n\
         \u{20}          L3 coordinator, once continuously and once under the\n\
         \u{20}          round-barrier baseline (results verified identical),\n\
         \u{20}          and writes the comparison to BENCH_coordinator.json;\n\
         \u{20}          with --cards > 1 the uniform and skewed-tenant mixes\n\
         \u{20}          additionally replay through an N-card fleet (affinity\n\
         \u{20}          vs round-robin routing, shared host ingress), appending\n\
         \u{20}          the fleet scaling block to the artifact\n\
         sweep      --clients-max <n> --queries-per-client <m> --queue-depth <d>\n\
         \u{20}          --arrival-rate <qps> --deadline-ms <f> --rows <n> --seed <s>\n\
         \u{20}          --cards <n> --cache-mib <n> --out <file.json> --point-dir <dir>\n\
         \u{20}          runs the open-loop client ladder (1..clients-max, powers\n\
         \u{20}          of two) per serving policy: seeded Poisson arrivals at a\n\
         \u{20}          rate calibrated to 2x measured capacity at the top rung,\n\
         \u{20}          a bounded admission queue with explicit backpressure and\n\
         \u{20}          load shedding, deadlines charged from arrival, and the\n\
         \u{20}          SLO-aware (EDF + tenant-fair) policy next to the\n\
         \u{20}          FIFO/fair/bandwidth baselines; every point is replayed\n\
         \u{20}          closed-loop to prove accepted results bit-identical and\n\
         \u{20}          every offered request accounted; writes one JSON per\n\
         \u{20}          point under --point-dir and the consolidated\n\
         \u{20}          BENCH_sweep.json with the saturated fifo-vs-slo block\n\
         chaos      --cards <n> --seed <s> --faults <none|standard|heavy>\n\
         \u{20}          --clients <n> --queries <m> --rows <n> --router <r>\n\
         \u{20}          --policy <p> --host-gbs <f> --out <file.json>\n\
         \u{20}          replays the serve fleet workload with a seeded fault\n\
         \u{20}          schedule armed (--seed seeds the faults; the workload\n\
         \u{20}          keeps its own seed, so --faults none reproduces the\n\
         \u{20}          fault-free fleet run), reconciles every ticket against\n\
         \u{20}          a fault-free reference (bit-identical or typed\n\
         \u{20}          failure, never lost), drives the DBMS executor's\n\
         \u{20}          graceful CPU degradation, and writes BENCH_chaos.json\n\
         \u{20}          (goodput, retries, failovers, downgrades, p99 vs the\n\
         \u{20}          fault-free twin)\n\
         trace      --rows <n> --repeat <r> --queries <m> --seed <s> --out <file.json>\n\
         \u{20}          --cards <n> --router <r> --fleet-out <file.json>\n\
         \u{20}          runs the analytics plan mix with the card-clock tracer\n\
         \u{20}          on (repeats warm the column cache), validates the span\n\
         \u{20}          stream against the scheduler's accounting for every\n\
         \u{20}          policy in both scheduling modes, and writes the\n\
         \u{20}          Perfetto-loadable TRACE_serve.json; with --cards > 1\n\
         \u{20}          also traces a fleet run (one track group and one\n\
         \u{20}          validation per card) into TRACE_fleet.json\n\
         bench-host --rows <n> --seed <s> --out <file.json>\n\
         \u{20}          measures the simulator's own wall-clock throughput on\n\
         \u{20}          the analytics plan mix (serial vs parallel functional\n\
         \u{20}          execution, cold vs physically-resident card) and writes\n\
         \u{20}          BENCH_host.json\n\
         help       this message"
    );
}

fn ctx_from(args: &Args) -> anyhow::Result<FigureCtx> {
    Ok(FigureCtx {
        scale: args.get_parsed("scale", 1.0 / 16.0)?,
        out_dir: Some(PathBuf::from(args.get_str("out", "results"))),
        seed: args.get_parsed("seed", 0xB00u64)?,
        artifacts: Some(PathBuf::from(args.get_str("artifacts", "artifacts"))),
    })
}

fn cmd_figures(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args)?;
    let which = args.get_str("fig", "all");
    let ids: Vec<&str> = if which == "all" {
        figures::all_ids().to_vec()
    } else {
        vec![which.as_str()]
    };
    for id in ids {
        let out = figures::run(id, &ctx)
            .ok_or_else(|| anyhow::anyhow!("unknown figure id '{id}' (try: {:?})", figures::all_ids()))?;
        println!("{}", out.render());
    }
    if let Some(dir) = &ctx.out_dir {
        println!("CSV series written to {dir:?}");
    }
    Ok(())
}

fn cmd_microbench(args: &Args) -> anyhow::Result<()> {
    let clock = match args.get_parsed("clock", 200u32)? {
        200 => FabricClock::Mhz200,
        300 => FabricClock::Mhz300,
        400 => FabricClock::Mhz400,
        c => anyhow::bail!("unsupported clock {c} MHz"),
    };
    let cfg = HbmConfig::at_clock(clock);
    let ports: Vec<usize> = args.get_list("ports", &[1, 2, 4, 8, 16, 32])?;
    let seps: Vec<u64> = args.get_list("separations", &[256, 192, 128, 64, 0])?;
    println!("HBM read bandwidth, {} MHz fabric clock:", clock.mhz());
    for (p, s, gbs) in fig2_sweep(&cfg, &ports, &seps) {
        println!("  {p:>2} ports, {s:>3} MiB separation: {gbs:>7.2} GB/s");
    }
    if args.get_bool("latency", false) {
        println!("single-access latency:");
        for k in [1usize, 2, 4, 8, 16, 32] {
            println!("  {k:>2} sharers: {:.0} ns", cfg.access_latency(k) * 1e9);
        }
    }
    Ok(())
}

fn cmd_resources(args: &Args) -> anyhow::Result<()> {
    let ctx = ctx_from(args)?;
    println!("{}", figures::table3(&ctx).render());
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let name = args.get_str("dataset", "tiny_ridge");
    let minibatch: usize = args.get_parsed("minibatch", 16)?;
    let (spec, artifact) = match name.as_str() {
        "tiny_ridge" => (
            DatasetSpec { name: "tiny", samples: 256, features: 32, task: TaskKind::Regression, epochs: 10 },
            format!("sgd_epoch_tiny_ridge_b{minibatch}"),
        ),
        "tiny_logistic" => (
            DatasetSpec { name: "tiny", samples: 256, features: 32, task: TaskKind::Binary, epochs: 10 },
            format!("sgd_epoch_tiny_logistic_b{minibatch}"),
        ),
        other => {
            let spec = hbm_analytics::workloads::datasets::by_name(other)
                .ok_or_else(|| anyhow::anyhow!("unknown dataset '{other}'"))?;
            (spec, format!("sgd_epoch_{}_b{minibatch}", other.to_lowercase()))
        }
    };
    let params = SgdHyperParams {
        task: spec.task.glm(),
        alpha: args.get_parsed("alpha", 0.05f32)?,
        lambda: args.get_parsed("lambda", 0.0f32)?,
        minibatch,
        epochs: args.get_parsed("epochs", spec.epochs)?,
    };
    println!("generating dataset {} ({} x {})...", spec.name, spec.samples, spec.features);
    let d = spec.generate(args.get_parsed("seed", 7u64)?);
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let mut rt = Runtime::new(&artifacts)?;
    println!("PJRT platform: {}", rt.platform());
    let exec = SgdEpochExecutor::new(&mut rt, &artifact, &d.features, &d.labels)?;
    println!("training via artifact '{artifact}' ({} epochs)...", params.epochs);
    let t0 = std::time::Instant::now();
    let (model, history) = exec.train(&mut rt, &params)?;
    let dt = t0.elapsed().as_secs_f64();
    for (e, x) in history.iter().enumerate() {
        let loss =
            hbm_analytics::cpu::sgd::loss(&d.features, &d.labels, spec.features, x, &params);
        println!("  epoch {:>3}: loss {loss:.6}", e + 1);
    }
    println!(
        "done in {dt:.2}s host wall-clock; |x| = {:.4}",
        model.iter().map(|v| (v * v) as f64).sum::<f64>().sqrt()
    );
    Ok(())
}

fn cmd_query(args: &Args) -> anyhow::Result<()> {
    use hbm_analytics::util::rng::Xoshiro256;
    let rows: usize = args.get_parsed("rows", 1_000_000)?;
    let offload = args.get_bool("offload", true);
    let engines: usize = args.get_parsed("engines", ENGINE_PORTS)?;
    anyhow::ensure!(
        (1..=ENGINE_PORTS).contains(&engines),
        "--engines must be in 1..={ENGINE_PORTS}, got {engines}"
    );
    let repeat: usize = args.get_parsed("repeat", 1)?;
    anyhow::ensure!(repeat >= 1, "--repeat must be positive");
    let mut rng = Xoshiro256::new(3);
    let keys: Vec<u32> = (0..rows as u32).collect();
    let vals: Vec<u32> = (0..rows).map(|_| rng.next_u32() % 10_000).collect();
    let mut cat = Catalog::new();
    cat.register(Table::new(
        "t",
        vec![Column::u32("key", keys), Column::u32("val", vals)],
    ));
    // SELECT count(*) FROM t WHERE val BETWEEN 100 AND 999
    let plan = Plan::scan("t", "key")
        .project(Plan::scan("t", "val").select(100, 999))
        .aggregate(hbm_analytics::db::ops::AggKind::Count);

    let t0 = std::time::Instant::now();
    let cpu_result = Executor::cpu(&cat, 8).run(&plan)?;
    let t_cpu = t0.elapsed();

    println!("CPU executor: {cpu_result:?} in {t_cpu:?}");
    if offload {
        // One persistent card across repeats: the executor lowers the
        // plan through `submit_plan` and names base columns with
        // (table, column) keys, so every run after the first finds them
        // HBM-resident and skips copy-in.
        let mut acc =
            FpgaAccelerator::new(HbmConfig::default()).with_engines(engines);
        for run in 0..repeat {
            let t1 = std::time::Instant::now();
            let fpga_result = Executor::accelerated(&cat, 8, &mut acc).run(&plan)?;
            let t_fpga = t1.elapsed();
            println!(
                "FPGA-offloaded executor ({engines} engines, run {}/{repeat}): \
                 {fpga_result:?} in {t_fpga:?} (host)",
                run + 1
            );
            assert_eq!(format!("{cpu_result:?}"), format!("{fpga_result:?}"));
        }
        let stats = acc.stats();
        println!(
            "results identical ✓; card served {} jobs, cache hits {} / misses {} \
             (simulated-device timings via `figures`)",
            stats.completed(),
            stats.cache.hits,
            stats.cache.misses
        );
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    use hbm_analytics::db::PipelineRequest;
    use hbm_analytics::util::table::Table as ReportTable;
    use hbm_analytics::workloads::analytics;

    let rows: usize = args.get_parsed("rows", 200_000)?;
    let repeat: usize = args.get_parsed("repeat", 2)?;
    let seed: u64 = args.get_parsed("seed", 11u64)?;
    anyhow::ensure!(rows > 0, "--rows must be positive");
    anyhow::ensure!(repeat > 0, "--repeat must be positive");
    let customers = (rows / 100).max(64);

    // The shared mixed-plan workload (workloads::analytics): its first
    // plan is the scan→select→join→aggregate shape whose probe side the
    // pipeline keeps on the card, where the operator-at-a-time walk
    // ships the projected intermediate back to the host and over the
    // link again.
    let cat = analytics::orders_catalog(rows, customers, seed);
    let plans = analytics::mixed_plans(customers);

    println!(
        "plan workload: {} plans x {repeat} runs over {rows} orders / \
         {customers} customers (seed {seed:#x})",
        plans.len()
    );
    let mut cpu_results = Vec::new();
    for (_, plan) in &plans {
        cpu_results.push(Executor::cpu(&cat, 8).run(plan)?);
    }

    // Operator-at-a-time reference: one blocking offload per operator,
    // every intermediate round-tripping through the host.
    let mut acc_op = FpgaAccelerator::new(HbmConfig::default());
    let mut op_bytes: Vec<Vec<u64>> = vec![Vec::new(); plans.len()];
    for _ in 0..repeat {
        for (pi, (name, plan)) in plans.iter().enumerate() {
            let before = acc_op.stats().total_copy_in_bytes();
            let r = Executor::accelerated(&cat, 8, &mut acc_op)
                .operator_at_a_time()
                .run(plan)?;
            anyhow::ensure!(
                r == cpu_results[pi],
                "operator-at-a-time diverged on {name}"
            );
            op_bytes[pi].push(acc_op.stats().total_copy_in_bytes() - before);
        }
    }

    // Pipelined: every run submits all plans as whole-query DAGs before
    // collecting any result, so they co-run on one card. One analyzer
    // cost model persists across the whole sequence, exactly like the
    // card's column cache, so its predicted copy-in bytes are
    // comparable to the measured total (exact while nothing is
    // evicted).
    let mut acc_pipe = FpgaAccelerator::new(HbmConfig::default());
    let mut cost = hbm_analytics::analyze::CostModel::new(
        hbm_analytics::coordinator::DEFAULT_CACHE_BYTES,
    );
    let mut predicted_total = 0u64;
    let mut pipe_bytes: Vec<Vec<u64>> = vec![Vec::new(); plans.len()];
    for run in 0..repeat {
        let mut handles = Vec::new();
        for (pi, (_, plan)) in plans.iter().enumerate() {
            let req = PipelineRequest::from_plan(plan, &cat)?.client(pi);
            predicted_total += cost.charge_plan(&req.facts());
            handles.push(acc_pipe.submit_plan(req));
        }
        println!(
            "run {}/{repeat}: {} pipelines in flight ({} stage jobs queued)",
            run + 1,
            handles.len(),
            acc_pipe.in_flight()
        );
        for (pi, handle) in handles.into_iter().enumerate() {
            let (r, report) = handle.take();
            anyhow::ensure!(
                r == cpu_results[pi],
                "pipeline diverged on {}",
                plans[pi].0
            );
            pipe_bytes[pi].push(report.copy_in_bytes());
        }
    }

    let mut t = ReportTable::new(
        "whole-plan pipelines vs operator-at-a-time (host bytes over the link)",
        &["plan", "run", "op-at-a-time B", "pipelined B", "saved %"],
    );
    for (pi, (name, _)) in plans.iter().enumerate() {
        for run in 0..repeat {
            let ob = op_bytes[pi][run];
            let pb = pipe_bytes[pi][run];
            let saved = if ob > 0 {
                100.0 * (ob as f64 - pb as f64) / ob as f64
            } else {
                0.0
            };
            t.row(vec![
                name.to_string(),
                (run + 1).to_string(),
                ob.to_string(),
                pb.to_string(),
                format!("{saved:.1}"),
            ]);
        }
    }
    println!("{}", t.render());

    let op_stats = acc_op.stats();
    let pipe_stats = acc_pipe.stats();
    let op_total = op_stats.total_copy_in_bytes();
    let pipe_total = pipe_stats.total_copy_in_bytes();
    println!(
        "results identical ✓; total copy-in {op_total} B operator-at-a-time \
         vs {pipe_total} B pipelined ({:.1}% saved)",
        100.0 * (op_total as f64 - pipe_total as f64) / op_total.max(1) as f64
    );
    anyhow::ensure!(
        pipe_total < op_total,
        "pipelining must move strictly fewer host bytes"
    );
    println!(
        "static analyzer predicted {predicted_total} B pipelined copy-in \
         (measured {pipe_total} B)"
    );
    // The cost model is exact only while nothing is evicted (it never
    // re-charges a key it admitted); under eviction pressure the real
    // card re-pays copy-ins the model does not, so enforce agreement
    // only in the eviction-free regime and report otherwise.
    if pipe_stats.cache.evictions == 0 {
        anyhow::ensure!(
            (predicted_total as f64 - pipe_total as f64).abs()
                <= 0.01 * pipe_total.max(1) as f64,
            "analyzer cost bound diverged from the measured copy-in \
             bytes (predicted {predicted_total}, measured {pipe_total})"
        );
    } else {
        println!(
            "note: {} eviction(s) — predicted copy-in is a lower bound, \
             not checked against the measured total",
            pipe_stats.cache.evictions
        );
    }

    let json_f = |v: f64| {
        if v.is_finite() {
            format!("{v:.9}")
        } else {
            "null".to_string()
        }
    };
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"plan_pipeline\",\n");
    json.push_str(&format!("  \"rows\": {rows},\n"));
    json.push_str(&format!("  \"repeat\": {repeat},\n"));
    json.push_str(&format!("  \"seed\": {seed},\n"));
    json.push_str("  \"plans\": [\n");
    for (pi, (name, _)) in plans.iter().enumerate() {
        json.push_str("    {\n");
        json.push_str(&format!("      \"name\": \"{name}\",\n"));
        let fmt_runs = |v: &[u64]| {
            v.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(", ")
        };
        json.push_str(&format!(
            "      \"operator_at_a_time_bytes\": [{}],\n",
            fmt_runs(&op_bytes[pi])
        ));
        json.push_str(&format!(
            "      \"pipelined_bytes\": [{}]\n",
            fmt_runs(&pipe_bytes[pi])
        ));
        json.push_str(if pi + 1 == plans.len() { "    }\n" } else { "    },\n" });
    }
    json.push_str("  ],\n");
    json.push_str("  \"operator_at_a_time\": {\n");
    json.push_str(&format!("    \"copy_in_bytes\": {op_total},\n"));
    json.push_str(&format!("    \"jobs\": {},\n", op_stats.completed()));
    json.push_str(&format!(
        "    \"simulated_seconds\": {}\n",
        json_f(op_stats.simulated_time)
    ));
    json.push_str("  },\n");
    json.push_str("  \"pipelined\": {\n");
    json.push_str(&format!("    \"copy_in_bytes\": {pipe_total},\n"));
    json.push_str(&format!("    \"predicted_copy_in_bytes\": {predicted_total},\n"));
    json.push_str(&format!("    \"jobs\": {},\n", pipe_stats.completed()));
    json.push_str(&format!("    \"cache_hits\": {},\n", pipe_stats.cache.hits));
    json.push_str(&format!(
        "    \"simulated_seconds\": {}\n",
        json_f(pipe_stats.simulated_time)
    ));
    json.push_str("  },\n");
    json.push_str("  \"savings\": {\n");
    json.push_str(&format!(
        "    \"copy_in_bytes\": {},\n",
        op_total.saturating_sub(pipe_total)
    ));
    json.push_str(&format!(
        "    \"fraction\": {}\n",
        json_f(1.0 - pipe_total as f64 / op_total.max(1) as f64)
    ));
    json.push_str("  }\n}\n");

    let out_path = args.get_str("out", "BENCH_pipeline.json");
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_check(args: &Args) -> anyhow::Result<()> {
    use hbm_analytics::analyze::{self, fixtures, CardSpec, Severity};
    use hbm_analytics::db::PipelineRequest;
    use hbm_analytics::fleet::Partitioner;
    use hbm_analytics::workloads::analytics;

    let fixture = args.get_str("fixture", "analytics");
    let out_path = args.get_str("out", "CHECK_report.json");
    let card = CardSpec::default();
    // --cards N lints each plan against the fleet card the cold router
    // would place it on (partitioner home of its first keyed column);
    // the route diagnostic names the card.
    let cards: usize = args.get_parsed("cards", 1usize)?;
    anyhow::ensure!(cards >= 1, "--cards must be positive");
    let partitioner_name = args.get_str("partitioner", "hash");
    let partitioner = Partitioner::parse(&partitioner_name).ok_or_else(|| {
        anyhow::anyhow!("unknown partitioner '{partitioner_name}' (hash|range)")
    })?;
    let fleet_specs: Vec<CardSpec> = vec![card.clone(); cards];

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"report\": \"check\",\n");
    json.push_str(&format!("  \"fixture\": \"{fixture}\",\n"));

    let (errors, warnings) = match fixture.as_str() {
        "analytics" => {
            let rows: usize = args.get_parsed("rows", 200_000)?;
            let seed: u64 = args.get_parsed("seed", 11u64)?;
            anyhow::ensure!(rows > 0, "--rows must be positive");
            let customers = (rows / 100).max(64);
            let cat = analytics::orders_catalog(rows, customers, seed);
            let plans = analytics::mixed_plans(customers);
            println!(
                "linting {} analytics plans over {rows} orders / {customers} \
                 customers (seed {seed:#x}) — nothing executes",
                plans.len()
            );
            let (mut errors, mut warnings) = (0, 0);
            json.push_str("  \"plans\": [\n");
            for (pi, (name, plan)) in plans.iter().enumerate() {
                let req = PipelineRequest::from_plan(plan, &cat)?;
                let (routed, report) = if cards > 1 {
                    analyze::analyze_request_fleet(&req, &fleet_specs, partitioner)
                } else {
                    (0, analyze::analyze_request(&req, &card))
                };
                errors += report.errors();
                warnings += report.warnings();
                println!(
                    "  {name}{}: {} error(s), {} warning(s), {} info(s); \
                     predicted copy-in {} B (cold card)",
                    if cards > 1 {
                        format!(" [card {routed}/{cards}]")
                    } else {
                        String::new()
                    },
                    report.errors(),
                    report.warnings(),
                    report.count(Severity::Info),
                    report.predicted_copy_in_bytes
                );
                for d in &report.diagnostics {
                    println!("    {d}");
                }
                json.push_str(&format!(
                    "    {{\"name\": \"{name}\", \"card\": {routed}, \"analysis\": "
                ));
                json.push_str(&report.to_json("    "));
                json.push('}');
                json.push_str(if pi + 1 == plans.len() { "\n" } else { ",\n" });
            }
            json.push_str("  ],\n");
            (errors, warnings)
        }
        "broken" => {
            let facts = fixtures::broken_plan_facts();
            let report = analyze::analyze_facts(&facts, &card);
            println!(
                "linting the intentionally broken fixture ({} stages):",
                facts.stages.len()
            );
            for d in &report.diagnostics {
                println!("  {d}");
            }
            let mut codes: Vec<&str> =
                report.diagnostics.iter().map(|d| d.code).collect();
            codes.sort_unstable();
            codes.dedup();
            json.push_str("  \"plans\": [\n");
            json.push_str("    {\"name\": \"broken\", \"analysis\": ");
            json.push_str(&report.to_json("    "));
            json.push_str("}\n  ],\n");
            json.push_str(&format!(
                "  \"codes\": [{}],\n",
                codes
                    .iter()
                    .map(|c| format!("\"{c}\""))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
            (report.errors(), report.warnings())
        }
        other => anyhow::bail!("unknown fixture '{other}' (analytics|broken)"),
    };

    json.push_str(&format!("  \"errors\": {errors},\n"));
    json.push_str(&format!("  \"warnings\": {warnings}\n"));
    json.push_str("}\n");
    std::fs::write(&out_path, json)?;
    println!("{errors} error(s), {warnings} warning(s); wrote {out_path}");
    if fixture == "analytics" {
        anyhow::ensure!(
            errors == 0,
            "the analytics workload must lint clean of errors"
        );
    }
    Ok(())
}

fn cmd_bench_host(args: &Args) -> anyhow::Result<()> {
    use hbm_analytics::bench::host;

    let spec = host::HostBenchSpec {
        rows: args.get_parsed("rows", 400_000usize)?,
        seed: args.get_parsed("seed", 0xB05u64)?,
    };
    anyhow::ensure!(spec.rows > 0, "--rows must be positive");
    println!(
        "bench-host: {} orders rows, 4 modes (serial/parallel x cold/resident), \
         host parallelism {}",
        spec.rows,
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
    );
    let report = host::run(&spec);
    println!("{}", report.render());
    anyhow::ensure!(
        report.probe_repeat_write_bytes == 0,
        "physically-resident repeat must write zero host bytes into HBM"
    );
    let out_path = args.get_str("out", "BENCH_host.json");
    std::fs::write(&out_path, host::bench_json(&report))?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    // Counts and capacities go through the validating accessors: `--cards
    // 0`, `--host-gbs 0` / `inf` / `NaN` all *parse* but poison the fleet
    // solvers downstream, so they are typed CLI errors here.
    let spec = ServeSpec {
        clients: args.get_count("clients", 4)?,
        queries: args.get_count("queries", 64)?,
        seed: args.get_parsed("seed", 0xC0FFEEu64)?,
        rows: args.get_count("rows", 48_000)?,
        cache_bytes: args.get_parsed("cache-mib", 4096u64)? * MIB,
    };
    let which = args.get_str("policy", "all");
    let policies: Vec<Policy> = if which == "all" {
        Policy::all().to_vec()
    } else {
        vec![Policy::parse(&which).ok_or_else(|| {
            anyhow::anyhow!("unknown policy '{which}' (fifo|fair|bandwidth|all)")
        })?]
    };

    let cards = args.get_count("cards", 1)?;
    let router_name = args.get_str("router", "affinity");
    let router = RouterKind::parse(&router_name).ok_or_else(|| {
        anyhow::anyhow!("unknown router '{router_name}' (affinity|round-robin)")
    })?;
    let host_gbs = args.get_positive_f64(
        "host-gbs",
        hbm_analytics::fleet::DEFAULT_HOST_BANDWIDTH / 1e9,
    )?;
    // The fleet bench replays one policy; honor a single --policy choice
    // and default to fair-share under --policy all.
    let fleet_policy =
        if policies.len() == 1 { policies[0] } else { Policy::FairShare };

    let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
    println!(
        "serving {} queries from {} clients ({} rows/column, seed {:#x})",
        spec.queries, spec.clients, spec.rows, spec.seed
    );
    let mut outcomes = Vec::new();
    for policy in policies {
        let jobs = coordinator::mixed_workload(&spec);
        let (outputs, outcome) = coordinator::run_policy(&cfg, policy, &spec, jobs);
        println!(
            "  {:<16} {} jobs in {:.3} ms simulated ({:.0} qps, {:.2}x vs \
             round barrier, overlap {:.1}%, cache hit {:.1}%)",
            outcome.policy.name(),
            outputs.len(),
            outcome.stats.simulated_time * 1e3,
            outcome.throughput_qps(),
            outcome.speedup(),
            outcome.stats.overlap_ratio() * 100.0,
            outcome.cache_hit_rate() * 100.0,
        );
        // Sanity floor with the same 1% fluid-composition slack the
        // dominance property test allows on arbitrary seeds; the CI
        // smoke asserts strict dominance on the pinned workload via jq.
        anyhow::ensure!(
            outcome.speedup() >= 0.99,
            "continuous scheduling lost throughput vs the round barrier \
             under {} ({:.3}x)",
            outcome.policy.name(),
            outcome.speedup()
        );
        outcomes.push(outcome);
    }
    println!("\n{}", coordinator::render_outcomes(&outcomes));

    // Fleet scale-out: replay the uniform mix and the skewed-tenant mix
    // through N cards under both routers (every replay re-verified
    // bit-identical to its single-card reference), and ride the results
    // along in the same JSON artifact under the `fleet` key.
    let fleet_bench = if cards > 1 {
        println!(
            "\nfleet: {cards} cards, {} router, {} policy, shared host \
             ingress {host_gbs:.1} GB/s",
            router.name(),
            fleet_policy.name()
        );
        let bench = coordinator::run_fleet_bench(
            &cfg,
            fleet_policy,
            &spec,
            cards,
            router,
            host_gbs * 1e9,
        );
        println!("{}", coordinator::render_fleet(&bench));
        println!(
            "uniform-mix scaling efficiency ({}): {:.3}",
            router.name(),
            bench.scaling_efficiency()
        );
        Some(bench)
    } else {
        None
    };

    let out_path = args.get_str("out", "BENCH_coordinator.json");
    std::fs::write(
        &out_path,
        coordinator::bench_json(&spec, &outcomes, fleet_bench.as_ref()),
    )?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    // Counts and rates go through the validating accessors: a zero
    // ladder top or queue bound, or a 0 / NaN / inf arrival rate or
    // deadline, all *parse* but poison the open-loop pump downstream,
    // so they are typed CLI errors here.
    let spec = serve_front::SweepSpec {
        clients_max: args.get_count("clients-max", 64)?,
        queries_per_client: args.get_count("queries-per-client", 6)?,
        queue_depth: args.get_count("queue-depth", 32)?,
        arrival_rate: if args.has("arrival-rate") {
            Some(args.get_positive_f64("arrival-rate", 1.0)?)
        } else {
            None
        },
        deadline: if args.has("deadline-ms") {
            Some(args.get_positive_f64("deadline-ms", 1.0)? * 1e-3)
        } else {
            None
        },
        rows: args.get_count("rows", 12_000)?,
        seed: args.get_parsed("seed", 0xC0FFEEu64)?,
        cards: args.get_count("cards", 1)?,
        cache_bytes: args.get_parsed("cache-mib", 4096u64)? * MIB,
    };
    let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
    println!(
        "sweeping open-loop clients 1..{} across serving policies \
         ({} queries/client/rung, queue bound {}, {} card{}, seed {:#x})",
        spec.clients_max,
        spec.queries_per_client,
        spec.queue_depth,
        spec.cards,
        if spec.cards == 1 { "" } else { "s" },
        spec.seed
    );
    let report = serve_front::run_sweep(&cfg, &spec);
    println!("{}", serve_front::render_sweep(&report));
    for p in &report.points {
        anyhow::ensure!(
            p.accounted,
            "point clients={} policy={} lost requests (offered {} != \
             completed {} + shed {} + rejected {} + expired {})",
            p.clients,
            p.policy,
            p.offered,
            p.completed,
            p.shed,
            p.rejected,
            p.expired
        );
        anyhow::ensure!(
            p.wrong == 0 && p.lost == 0,
            "point clients={} policy={} failed replay verification \
             (wrong {}, lost {})",
            p.clients,
            p.policy,
            p.wrong,
            p.lost
        );
        anyhow::ensure!(
            p.max_queue_depth <= p.queue_bound,
            "point clients={} policy={} exceeded the admission bound \
             ({} > {})",
            p.clients,
            p.policy,
            p.max_queue_depth,
            p.queue_bound
        );
    }

    let point_dir = args.get_str("point-dir", "SWEEP");
    std::fs::create_dir_all(&point_dir)?;
    for p in &report.points {
        let path =
            format!("{point_dir}/point_c{}_{}.json", p.clients, p.policy);
        std::fs::write(&path, format!("{}\n", serve_front::point_json(p)))?;
    }
    let out_path = args.get_str("out", "BENCH_sweep.json");
    std::fs::write(&out_path, serve_front::sweep_json(&report))?;
    println!(
        "wrote {out_path} and {} per-point files under {point_dir}/",
        report.points.len()
    );
    Ok(())
}

fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    use hbm_analytics::fault::FaultPlan;

    // The workload shape mirrors the CI fleet smoke (`serve --clients 4
    // --queries 128 --rows 24000 --cards 4 --router affinity`), and
    // `--seed` seeds only the fault schedule: with `--faults none` this
    // replays exactly the serve fleet run, so its goodput is directly
    // comparable to the serve artifact's fleet qps.
    let spec = ServeSpec {
        clients: args.get_count("clients", 4)?,
        queries: args.get_count("queries", 128)?,
        seed: args.get_parsed("workload-seed", 0xC0FFEEu64)?,
        rows: args.get_count("rows", 24_000)?,
        cache_bytes: args.get_parsed("cache-mib", 4096u64)? * MIB,
    };
    let cards = args.get_count("cards", 4)?;
    let seed: u64 = args.get_parsed("seed", 7u64)?;
    let mix = args.get_str("faults", "standard");
    let plan = FaultPlan::parse_mix(&mix, seed, cards).map_err(|e| anyhow::anyhow!(e))?;
    let router_name = args.get_str("router", "affinity");
    let router = RouterKind::parse(&router_name).ok_or_else(|| {
        anyhow::anyhow!("unknown router '{router_name}' (affinity|round-robin)")
    })?;
    let policy_name = args.get_str("policy", "fair");
    let policy = Policy::parse(&policy_name).ok_or_else(|| {
        anyhow::anyhow!("unknown policy '{policy_name}' (fifo|fair|bandwidth)")
    })?;
    let host_gbs = args.get_positive_f64(
        "host-gbs",
        hbm_analytics::fleet::DEFAULT_HOST_BANDWIDTH / 1e9,
    )?;

    let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
    println!(
        "chaos: {} queries on {cards} cards, '{}' fault mix (seed {seed:#x}, \
         {} scheduled faults), {} router, {} policy",
        spec.queries,
        plan.mix,
        plan.faults.len(),
        router.name(),
        policy.name()
    );
    let outcome = coordinator::run_chaos(
        &cfg,
        policy,
        &spec,
        cards,
        router,
        host_gbs * 1e9,
        &plan,
    );
    let db = coordinator::run_chaos_db(&cfg, &mix);
    println!("{}", coordinator::render_chaos(&outcome, &db));
    anyhow::ensure!(
        outcome.wrong == 0,
        "{} surviving output(s) diverged from the fault-free reference",
        outcome.wrong
    );
    anyhow::ensure!(
        outcome.lost == 0,
        "{} ticket(s) vanished without a typed failure",
        outcome.lost
    );
    anyhow::ensure!(
        db.matches_cpu,
        "a degraded query diverged from the CPU executor"
    );
    println!(
        "chaos goodput {:.0} qps vs fault-free {:.0} qps \
         ({} retries, {} failovers, {} downgrades); every surviving \
         result bit-identical ✓",
        outcome.goodput_qps,
        outcome.fault_free_qps,
        outcome.retries,
        outcome.failovers,
        db.downgrades
    );
    let out_path = args.get_str("out", "BENCH_chaos.json");
    std::fs::write(
        &out_path,
        coordinator::chaos_json(&spec, policy, host_gbs * 1e9, &outcome, &db),
    )?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use hbm_analytics::db::PipelineRequest;
    use hbm_analytics::trace;
    use hbm_analytics::workloads::analytics;

    let rows: usize = args.get_parsed("rows", 100_000)?;
    let repeat: usize = args.get_parsed("repeat", 2)?;
    let seed: u64 = args.get_parsed("seed", 11u64)?;
    anyhow::ensure!(rows > 0, "--rows must be positive");
    anyhow::ensure!(repeat > 0, "--repeat must be positive");
    let customers = (rows / 100).max(64);

    // 1. Traced whole-pipeline run of the analytics plan mix. Repeats
    // reuse one card, so runs after the first hit the HBM-resident
    // column cache — the trace must witness those hits.
    let cat = analytics::orders_catalog(rows, customers, seed);
    let plans = analytics::mixed_plans(customers);
    let mut acc = FpgaAccelerator::new(HbmConfig::default());
    acc.set_tracing(true);
    println!(
        "tracing {} plans x {repeat} runs over {rows} orders / {customers} \
         customers (seed {seed:#x})",
        plans.len()
    );
    let mut reports: Vec<(&str, usize, hbm_analytics::db::PipelineReport)> =
        Vec::new();
    for run in 0..repeat {
        let mut handles = Vec::new();
        for (pi, (_, plan)) in plans.iter().enumerate() {
            let req = PipelineRequest::from_plan(plan, &cat)?.client(pi);
            handles.push(acc.submit_plan(req));
        }
        for (pi, handle) in handles.into_iter().enumerate() {
            let (_, report) = handle.take();
            reports.push((plans[pi].0, run + 1, report));
        }
    }
    let pipe_events = acc.take_trace();
    let pipe_stats = acc.stats();
    let pipe_validation = trace::validate(&pipe_events, pipe_stats.view());
    let hit_rate = pipe_stats.cache.hit_rate();
    println!(
        "  {} events; cache hits {} / misses {} ({:.1}% hit rate, {} B \
         copy-in avoided)",
        pipe_events.len(),
        pipe_stats.cache.hits,
        pipe_stats.cache.misses,
        hit_rate * 100.0,
        pipe_stats.cache.bytes_avoided()
    );
    println!("  {}", pipe_validation.summary());
    anyhow::ensure!(pipe_validation.passed(), "pipeline trace failed validation");
    if repeat > 1 {
        anyhow::ensure!(
            hit_rate > 0.0,
            "repeat runs on one card must hit the column cache"
        );
    }

    println!("  per-stage span breakdowns (simulated seconds):");
    for (name, run, report) in &reports {
        for (si, breakdown) in
            report.stage_breakdowns(&pipe_events).iter().enumerate()
        {
            let b = breakdown.expect("traced stage has spans");
            println!(
                "    {name} run {run} stage {si}: wait {:.6} copy-in {:.6} \
                 run {:.6} copy-out {:.6} ({} dispatches)",
                b.waiting, b.copy_in, b.running, b.copy_out, b.dispatches
            );
        }
    }

    // 2. Validation matrix: every policy in both scheduling modes over
    // the serve harness's mixed workload — the trace re-derives the
    // scheduler's aggregate accounting and must match it everywhere.
    let spec = ServeSpec {
        clients: args.get_parsed("clients", 4usize)?,
        queries: args.get_parsed("queries", 32usize)?,
        seed: args.get_parsed("serve-seed", 0xC0FFEEu64)?,
        rows: args.get_parsed("serve-rows", 24_000usize)?,
        cache_bytes: args.get_parsed("cache-mib", 4096u64)? * MIB,
    };
    let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
    println!(
        "validation matrix: {} queries from {} clients per policy and mode",
        spec.queries, spec.clients
    );
    let mut validations = Vec::new();
    for policy in Policy::all() {
        for barrier in [false, true] {
            let (events, stats) =
                coordinator::run_traced(&cfg, policy, barrier, &spec);
            let v = trace::validate(&events, stats.view());
            let mode = if barrier { "round_barrier" } else { "continuous" };
            println!("  {:<16} {mode:<14} {}", policy.name(), v.summary());
            anyhow::ensure!(
                v.passed(),
                "trace validation failed for {} ({mode})",
                policy.name()
            );
            validations.push((policy, barrier, v));
        }
    }

    let json_f = |v: f64| {
        if v.is_finite() {
            format!("{v:.9}")
        } else {
            "null".to_string()
        }
    };
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"displayTimeUnit\": \"ms\",\n");
    json.push_str(&format!(
        "  \"traceEvents\": {},\n",
        trace::trace_events_json(&pipe_events)
    ));
    json.push_str(&format!("  \"cache_hit_rate\": {},\n", json_f(hit_rate)));
    json.push_str(&format!(
        "  \"cache_bytes_avoided\": {},\n",
        pipe_stats.cache.bytes_avoided()
    ));
    json.push_str(&format!(
        "  \"pipeline_validation_passed\": {},\n",
        pipe_validation.passed()
    ));
    json.push_str("  \"validation\": [\n");
    for (i, (policy, barrier, v)) in validations.iter().enumerate() {
        json.push_str("    {\n");
        json.push_str(&format!("      \"policy\": \"{}\",\n", policy.name()));
        json.push_str(&format!(
            "      \"mode\": \"{}\",\n",
            if *barrier { "round_barrier" } else { "continuous" }
        ));
        json.push_str(&format!("      \"passed\": {},\n", v.passed()));
        json.push_str(&format!("      \"jobs_checked\": {},\n", v.jobs_checked));
        json.push_str(&format!(
            "      \"engine_busy_derived\": {},\n",
            json_f(v.engine_busy_derived)
        ));
        json.push_str(&format!(
            "      \"engine_busy_expected\": {},\n",
            json_f(v.engine_busy_expected)
        ));
        json.push_str(&format!(
            "      \"link_busy_derived\": {},\n",
            json_f(v.link_busy_derived)
        ));
        json.push_str(&format!(
            "      \"link_busy_expected\": {},\n",
            json_f(v.link_busy_expected)
        ));
        json.push_str(&format!(
            "      \"overlap_derived\": {},\n",
            json_f(v.overlap_derived)
        ));
        json.push_str(&format!(
            "      \"overlap_expected\": {},\n",
            json_f(v.overlap_expected)
        ));
        json.push_str(&format!(
            "      \"max_latency_error\": {}\n",
            json_f(v.max_latency_error)
        ));
        json.push_str(if i + 1 == validations.len() {
            "    }\n"
        } else {
            "    },\n"
        });
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"metrics\": {},\n",
        trace::MetricsRegistry::from_events(&pipe_events).to_json("  ")
    ));
    json.push_str("  \"pipeline_stages\": [\n");
    let mut first = true;
    for (name, run, report) in &reports {
        for (si, breakdown) in
            report.stage_breakdowns(&pipe_events).iter().enumerate()
        {
            let Some(b) = breakdown else { continue };
            if !first {
                json.push_str(",\n");
            }
            first = false;
            json.push_str(&format!(
                "    {{\"plan\": \"{name}\", \"run\": {run}, \"stage\": {si}, \
                 \"waiting_s\": {}, \"copy_in_s\": {}, \"running_s\": {}, \
                 \"copy_out_s\": {}, \"dispatches\": {}}}",
                json_f(b.waiting),
                json_f(b.copy_in),
                json_f(b.running),
                json_f(b.copy_out),
                b.dispatches
            ));
        }
    }
    json.push_str("\n  ]\n}\n");

    let out_path = args.get_str("out", "TRACE_serve.json");
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path} (load it in Perfetto / chrome://tracing)");

    // 3. Fleet traces: one event stream per card, each on its own card
    // clock, rendered as one Perfetto track group per card and validated
    // card-by-card against that card's own accounting.
    let cards: usize = args.get_parsed("cards", 1usize)?;
    anyhow::ensure!(cards >= 1, "--cards must be positive");
    if cards > 1 {
        let router_name = args.get_str("router", "affinity");
        let router = RouterKind::parse(&router_name).ok_or_else(|| {
            anyhow::anyhow!("unknown router '{router_name}' (affinity|round-robin)")
        })?;
        println!(
            "fleet trace: {} queries over {cards} cards ({} router)",
            spec.queries,
            router.name()
        );
        let (streams, fleet_stats) = coordinator::run_fleet_traced(
            &cfg,
            Policy::FairShare,
            &spec,
            cards,
            router,
        );
        let reports = trace::validate_cards(
            streams
                .iter()
                .zip(&fleet_stats)
                .map(|(events, stats)| (events.as_slice(), stats.view())),
        );
        for (card, v) in reports.iter().enumerate() {
            println!("  card {card}: {}", v.summary());
            anyhow::ensure!(
                v.passed(),
                "fleet trace validation failed on card {card}"
            );
        }
        let fleet_path = args.get_str("fleet-out", "TRACE_fleet.json");
        std::fs::write(&fleet_path, trace::fleet_chrome_trace(&streams))?;
        println!("wrote {fleet_path} ({cards} per-card track groups)");
    }
    Ok(())
}
