//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the subset of anyhow's API the workspace actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait (on both
//! `Result` and `Option`), and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics follow anyhow where they matter to callers:
//!
//! * `{}` displays the outermost message only; `{:#}` displays the whole
//!   context chain separated by `": "` (the form `hbmctl` prints);
//! * `Error` deliberately does **not** implement `std::error::Error`, so
//!   the blanket `From<E: std::error::Error>` conversion (what makes `?`
//!   work) cannot conflict with the reflexive `From<Error>`;
//! * source chains of converted errors are preserved for `{:#}`.

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: an outermost message plus an optional cause.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message (what `anyhow!` builds).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, msg) in self.chain().into_iter().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{msg}")?;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Flatten the std source chain into nested context frames so
        // `{:#}` shows the full story.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut it = msgs.into_iter().rev();
        let mut err = Error { msg: it.next().unwrap(), source: None };
        for msg in it {
            err = Error { msg, source: Some(Box::new(err)) };
        }
        err
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_renders_in_alternate_mode() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        fn fails() -> Result<()> {
            ensure!(1 + 1 == 3, "math broke: {}", 42);
            Ok(())
        }
        assert_eq!(format!("{}", fails().unwrap_err()), "math broke: 42");
        let e = anyhow!("plain {}", "msg");
        assert_eq!(format!("{e}"), "plain msg");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
