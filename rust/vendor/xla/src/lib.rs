//! Offline API stub for the `xla` crate (xla-rs PJRT bindings).
//!
//! The build environment has neither crates.io access nor an XLA/PJRT
//! shared library, so this shim provides just enough surface for the
//! `runtime` layer to **compile**: [`Literal`] is fully functional
//! (host-side typed buffers), while everything that would need a real
//! PJRT runtime ([`PjRtClient::cpu`], compilation, execution) returns a
//! clear "PJRT unavailable" error at *runtime*. Code paths gated on
//! artifacts being present (`runtime_integration.rs`, `hbmctl train`)
//! degrade to a clean skip/error instead of failing the build.
//!
//! Replace the `xla` entry in `rust/Cargo.toml` with the real xla-rs
//! dependency to run the HLO artifacts end-to-end.

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT unavailable (offline `xla` stub; swap in real xla-rs to execute HLO)"
    ))
}

/// Element types the shimmed [`Literal`] can hold. Public only because
/// the [`NativeType`] conversion trait names it; not part of the API.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    U32(Vec<u32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::F64(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::I64(v) => v.len(),
            Data::U32(v) => v.len(),
        }
    }
}

/// Conversion between native element types and [`Data`] storage.
pub trait NativeType: Copy + Sized {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $variant:ident) => {
        impl NativeType for $t {
            fn wrap(v: Vec<Self>) -> Data {
                Data::$variant(v)
            }
            fn unwrap(d: &Data) -> Option<Vec<Self>> {
                match d {
                    Data::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(f64, F64);
native!(i32, I32);
native!(i64, I64);
native!(u32, U32);

/// A host-side typed buffer with a shape, mirroring xla-rs's `Literal`.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        let dims = vec![values.len() as i64];
        Literal { data: T::wrap(values.to_vec()), dims }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal { data: T::wrap(vec![value]), dims: Vec::new() }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the elements out as a flat vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error("to_vec: element type mismatch".to_string()))
    }

    /// Split a tuple literal into its parts. Tuples only arise from real
    /// PJRT execution, which the stub cannot perform.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("decompose_tuple"))
    }
}

/// Parsed HLO module handle (stub: parsing needs real XLA).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("parsing HLO text"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle (stub: construction always fails cleanly).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling computation"))
    }
}

/// Compiled executable handle (stub: never constructable in practice).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetching buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        assert!(s.dims().is_empty());
    }

    #[test]
    fn runtime_surface_fails_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("PJRT unavailable"));
    }
}
