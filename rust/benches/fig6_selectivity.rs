//! Bench: Fig. 6 — selectivity sweep. Regenerates the figure and times
//! the worst case (100% selectivity: full egress traffic + compaction).

use hbm_analytics::bench::figures::{fig6, FigureCtx};
use hbm_analytics::bench::harness::{black_box, Bencher};
use hbm_analytics::db::{FpgaAccelerator, OffloadRequest};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::workloads::SelectionWorkload;

fn main() {
    let ctx = FigureCtx { out_dir: None, ..Default::default() };
    println!("{}", fig6(&ctx).render());

    let items = 2_000_000u64;
    let w = SelectionWorkload::uniform(items, 1.0, 2);
    let b = Bencher::quick();
    let r = b.run_throughput("select offload sel=100% (2M items)", items * 4, || {
        let mut acc = FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
        black_box(
            acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
                .wait_selection(),
        );
    });
    println!("{}", r.report());
}
