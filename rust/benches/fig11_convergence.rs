//! Bench: Fig. 11 — convergence vs minibatch size, through the PJRT
//! runtime when artifacts exist. Also times one HLO-executed epoch (the
//! L1/L2 request-path hot loop).

use std::path::PathBuf;

use hbm_analytics::bench::figures::{fig11, FigureCtx};
use hbm_analytics::bench::harness::{black_box, Bencher};
use hbm_analytics::runtime::{Runtime, SgdEpochExecutor};
use hbm_analytics::workloads::datasets::{DatasetSpec, TaskKind};

fn main() {
    // The figure itself (runtime-backed if artifacts are present).
    let ctx = FigureCtx {
        out_dir: None,
        scale: 1.0 / 64.0,
        artifacts: Some(PathBuf::from("artifacts")),
        ..Default::default()
    };
    println!("{}", fig11(&ctx).render());

    // Hot-path timing: one HLO epoch on the tiny artifact.
    let Ok(mut rt) = Runtime::from_default_dir() else {
        eprintln!("artifacts missing; skipping HLO epoch timing");
        return;
    };
    let d = DatasetSpec {
        name: "tiny",
        samples: 256,
        features: 32,
        task: TaskKind::Regression,
        epochs: 1,
    }
    .generate(8);
    let exec =
        SgdEpochExecutor::new(&mut rt, "sgd_epoch_tiny_ridge_b16", &d.features, &d.labels)
            .expect("executor");
    let x = vec![0.0f32; 32];
    let b = Bencher { warmup: 3, iters: 20 };
    let r = b.run_throughput("HLO epoch tiny (256x32, B=16)", d.spec.bytes(), || {
        black_box(exec.epoch(&mut rt, &x, 0.05, 0.0).unwrap());
    });
    println!("{}", r.report());
}
