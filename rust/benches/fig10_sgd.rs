//! Bench: Fig. 10a/10b — SGD scaling and dataset sweep. Regenerates both
//! and times the native trainer epoch (the engine's functional core).

use hbm_analytics::bench::figures::{fig10a, fig10b, FigureCtx};
use hbm_analytics::bench::harness::{black_box, Bencher};
use hbm_analytics::cpu;
use hbm_analytics::engines::sgd::{GlmTask, SgdHyperParams};
use hbm_analytics::workloads::datasets::{DatasetSpec, TaskKind};

fn main() {
    let ctx = FigureCtx { out_dir: None, ..Default::default() };
    println!("{}", fig10a(&ctx).render());
    println!("{}", fig10b(&ctx).render());

    let spec = DatasetSpec {
        name: "bench",
        samples: 4096,
        features: 256,
        task: TaskKind::Regression,
        epochs: 1,
    };
    let d = spec.generate(6);
    let params = SgdHyperParams {
        task: GlmTask::Ridge,
        alpha: 0.05,
        lambda: 0.0,
        minibatch: 16,
        epochs: 1,
    };
    let b = Bencher::default();
    let r = b.run_throughput("sgd epoch 4096x256 (native)", spec.bytes(), || {
        black_box(cpu::sgd::train(&d.features, &d.labels, 256, &params));
    });
    println!("{}", r.report());
}
