//! Bench: Table I — join configuration matrix. Regenerates the table and
//! times the best-case probe path (II=1, no collision handling) end to
//! end, copy-in included.

use hbm_analytics::bench::figures::{table1, FigureCtx};
use hbm_analytics::bench::harness::{black_box, Bencher};
use hbm_analytics::db::{FpgaAccelerator, OffloadRequest};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::workloads::JoinWorkload;

fn main() {
    let ctx = FigureCtx { out_dir: None, ..Default::default() };
    println!("{}", table1(&ctx).render());

    let w = JoinWorkload::generate(4_000_000, 4096, true, true, 3);
    let b = Bencher::quick();
    let r = b.run_throughput(
        "join offload 7 engines, II=1 (4M tuples)",
        (w.l.len() * 4) as u64,
        || {
            let mut acc =
                FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
            black_box(
                acc.submit(OffloadRequest::join(&w.s, &w.l).collisions(false))
                    .wait_join(),
            );
        },
    );
    println!("{}", r.report());
}
