//! Bench: Table I — join configuration matrix. Regenerates the table and
//! times the best-case probe path (II=1, resident L) end to end.

use hbm_analytics::bench::figures::{table1, FigureCtx};
use hbm_analytics::bench::harness::{black_box, Bencher};
use hbm_analytics::db::FpgaAccelerator;
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::workloads::JoinWorkload;

fn main() {
    let ctx = FigureCtx { out_dir: None, ..Default::default() };
    println!("{}", table1(&ctx).render());

    let w = JoinWorkload::generate(4_000_000, 4096, true, true, 3);
    let b = Bencher::quick();
    let r = b.run_throughput(
        "offload_join 7 engines, II=1 (4M tuples)",
        (w.l.len() * 4) as u64,
        || {
            let mut acc = FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200))
                .resident();
            black_box(acc.offload_join_cfg(&w.s, &w.l, false));
        },
    );
    println!("{}", r.report());
}
