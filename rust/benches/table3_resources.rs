//! Bench: Table III — resource model + floorplan. Regenerates the table
//! and times the floorplanner (trivially fast; included for completeness
//! of the one-bench-per-table rule).

use hbm_analytics::bench::figures::{table2, table3, FigureCtx};
use hbm_analytics::bench::harness::{black_box, Bencher};
use hbm_analytics::floorplan::{floorplan, BitstreamSpec, EngineKind};

fn main() {
    let ctx = FigureCtx { out_dir: None, ..Default::default() };
    println!("{}", table2(&ctx).render());
    println!("{}", table3(&ctx).render());

    let b = Bencher::default();
    let r = b.run("floorplan all three bitstreams", || {
        for kind in [EngineKind::Selection, EngineKind::Join, EngineKind::Sgd] {
            black_box(floorplan(&BitstreamSpec {
                kind,
                engines: kind.paper_engines(),
            }));
        }
    });
    println!("{}", r.report());
}
