//! Bench: Fig. 8a/8b — join scaling and |S| sweep. Regenerates both and
//! times the CPU hash join on this host (Algorithm 2 functional path).

use hbm_analytics::bench::figures::{fig8a, fig8b, FigureCtx};
use hbm_analytics::bench::harness::{black_box, Bencher};
use hbm_analytics::cpu;
use hbm_analytics::workloads::JoinWorkload;

fn main() {
    let ctx = FigureCtx { out_dir: None, ..Default::default() };
    println!("{}", fig8a(&ctx).render());
    println!("{}", fig8b(&ctx).render());

    let w = JoinWorkload::generate(8_000_000, 4096, true, true, 4);
    let b = Bencher::quick();
    let r = b.run_throughput(
        "cpu hash_join 8 threads (8M probe tuples)",
        (w.l.len() * 4) as u64,
        || {
            black_box(cpu::join::hash_join_positions(&w.s, &w.l, 8));
        },
    );
    println!("{}", r.report());
}
