//! Bench: Fig. 5a/5b — selection scaling. Regenerates both figures and
//! times the end-to-end 14-engine offload (functional scan + fluid sim)
//! plus the CPU baseline scan on this host.

use hbm_analytics::bench::figures::{fig5a, fig5b, FigureCtx};
use hbm_analytics::bench::harness::{black_box, Bencher};
use hbm_analytics::cpu;
use hbm_analytics::db::{FpgaAccelerator, OffloadRequest};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::workloads::SelectionWorkload;

fn main() {
    let ctx = FigureCtx { out_dir: None, ..Default::default() };
    println!("{}", fig5a(&ctx).render());
    println!("{}", fig5b(&ctx).render());

    let items = 8_000_000u64;
    let w = SelectionWorkload::uniform(items, 0.0, 1);
    let bytes = items * 4;
    let b = Bencher::quick();
    let r = b.run_throughput("select offload 14 engines (8M items)", bytes, || {
        let mut acc = FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
        black_box(
            acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
                .wait_selection(),
        );
    });
    println!("{}", r.report());
    let r = b.run_throughput("cpu range_select 8 threads (8M items)", bytes, || {
        black_box(cpu::selection::range_select(&w.data, w.lo, w.hi, 8));
    });
    println!("{}", r.report());
}
