//! Bench: Fig. 2 — HBM bandwidth sweep (microbenchmark infrastructure).
//! Regenerates the figure and times the crossbar fluid solver (the L3
//! timing-model hot path).

use hbm_analytics::bench::figures::{fig2, FigureCtx};
use hbm_analytics::bench::harness::{black_box, Bencher};
use hbm_analytics::hbm::{fig2_sweep, FabricClock, HbmConfig};

fn main() {
    let ctx = FigureCtx { out_dir: None, ..Default::default() };
    println!("{}", fig2(&ctx).render());

    let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
    let b = Bencher::default();
    let r = b.run("fig2 full sweep (30 solves)", || {
        black_box(fig2_sweep(
            &cfg,
            &[1, 2, 4, 8, 16, 32],
            &[256, 192, 128, 64, 0],
        ));
    });
    println!("{}", r.report());
}
