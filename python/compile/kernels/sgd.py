"""Layer-1 Pallas kernel: the SGD minibatch pipeline of the paper's Fig. 9.

The FPGA engine is a Dot -> ScalarEngine -> Update dataflow pipeline over
16-float lines with the model vector x held on-chip (URAM). The TPU
rethink (DESIGN.md `§Hardware-Adaptation`): one fused kernel per minibatch
that keeps x in VMEM, computes the B dot products on the VPU's (8, 128)
lanes (the minibatch maps to the sublane dimension), applies the scalar
nonlinearity, and applies the rank-1 (rank-B) gradient update — one VMEM
round-trip where a naive HLO graph would take three. The RAW dependency
the paper preserves (update before the next minibatch's dots) is the
sequential grid dimension in :func:`sgd_epoch_kernel`'s caller
(`model.sgd_epoch` scans minibatches in order).

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and the AOT HLO must run everywhere. See
/opt/xla-example/README.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tasks (mirror rust/src/engines/sgd.rs GlmTask).
RIDGE = 0
LOGISTIC = 1


def _minibatch_kernel(task, x_ref, a_ref, b_ref, alpha_ref, lam_ref, out_ref):
    """One minibatch update, entirely in VMEM.

    x_ref:     (n,)   current model
    a_ref:     (B, n) minibatch features
    b_ref:     (B,)   minibatch labels
    alpha_ref: (1,)   step size
    lam_ref:   (1,)   L2 regularization
    out_ref:   (n,)   updated model
    """
    x = x_ref[...]
    a = a_ref[...]
    b = b_ref[...]
    alpha = alpha_ref[0]
    lam = lam_ref[0]
    # Dot module: B dot products on the vector unit.
    dot = a @ x  # (B,)
    # ScalarEngine: residual (with the task's nonlinearity).
    if task == LOGISTIC:
        pred = 1.0 / (1.0 + jnp.exp(-dot))
    else:
        pred = dot
    d = pred - b  # (B,)
    # Update module: g = a^T d (rank-B update), then the model step with
    # L2 shrinkage — Algorithm 3 line 7.
    g = d @ a  # (n,)
    bsz = jnp.asarray(a.shape[0], dtype=x.dtype)
    out_ref[...] = x - alpha * (g / bsz) - alpha * 2.0 * lam * x


@functools.partial(jax.jit, static_argnames=("task",))
def sgd_minibatch(x, a, b, alpha, lam, *, task=RIDGE):
    """Apply one minibatch SGD step via the Pallas kernel.

    Args:
      x: (n,) f32 model.
      a: (B, n) f32 minibatch features.
      b: (B,) f32 labels.
      alpha, lam: scalars (passed as shape-(1,) arrays).
      task: RIDGE or LOGISTIC (static).

    Returns: (n,) f32 updated model.
    """
    n = x.shape[0]
    alpha = jnp.asarray(alpha, jnp.float32).reshape((1,))
    lam = jnp.asarray(lam, jnp.float32).reshape((1,))
    kernel = functools.partial(_minibatch_kernel, task)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, a, b, alpha, lam)
