"""Pure-jnp/numpy oracles for the Pallas kernels — the build-time
correctness signal (pytest compares kernel outputs against these).

Definitions mirror Algorithm 1 (selection) and Algorithm 3 (SGD) of the
paper, and rust/src/engines/{selection,sgd}.rs on the coordinator side.
"""

import jax.numpy as jnp
import numpy as np

RIDGE = 0
LOGISTIC = 1


def sgd_minibatch_ref(x, a, b, alpha, lam, task=RIDGE):
    """One minibatch SGD step, straight-line jnp (no pallas)."""
    x = jnp.asarray(x, jnp.float32)
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    dot = a @ x
    pred = 1.0 / (1.0 + jnp.exp(-dot)) if task == LOGISTIC else dot
    d = pred - b
    g = d @ a
    bsz = jnp.float32(a.shape[0])
    return x - alpha * (g / bsz) - alpha * 2.0 * lam * x


def sgd_epoch_ref(x, features, labels, alpha, lam, minibatch, task=RIDGE):
    """Full epoch over row-major features, minibatch at a time (numpy)."""
    x = np.asarray(x, np.float32).copy()
    features = np.asarray(features, np.float32)
    labels = np.asarray(labels, np.float32)
    m = labels.shape[0]
    for s in range(0, (m // minibatch) * minibatch, minibatch):
        a = features[s : s + minibatch]
        b = labels[s : s + minibatch]
        dot = a @ x
        pred = (1.0 / (1.0 + np.exp(-dot))) if task == LOGISTIC else dot
        d = (pred - b).astype(np.float32)
        g = d @ a
        x = (x - alpha * (g / np.float32(minibatch)) - alpha * 2.0 * lam * x).astype(
            np.float32
        )
    return x


def glm_loss_ref(x, features, labels, lam, task=RIDGE):
    """Regularized training loss (Eq. 1), float64 numpy."""
    z = np.asarray(features, np.float64) @ np.asarray(x, np.float64)
    b = np.asarray(labels, np.float64)
    if task == LOGISTIC:
        per = np.logaddexp(0.0, z) - b * z
    else:
        per = 0.5 * (z - b) ** 2
    reg = lam * float(np.dot(np.asarray(x, np.float64), np.asarray(x, np.float64)))
    return float(np.mean(per) + reg)


def range_select_ref(data, lo, hi):
    """Match mask + indexes, numpy."""
    data = np.asarray(data)
    mask = ((data >= lo) & (data <= hi)).astype(np.int32)
    idx = np.nonzero(mask)[0].astype(np.int32)
    return mask, idx
