"""Layer-1 Pallas kernel: the 16-lane range-selection core (paper Fig. 4).

The FPGA ingress pipeline compares 16 values per cycle against [lo, hi]
and buffers matching indexes per lane. TPU mapping (DESIGN.md
`§Hardware-Adaptation`): a tiled compare over VMEM blocks producing a
match mask and a per-block match count; the block index map is the
direct analogue of the per-engine channel partitioning (tile i reads HBM
slice i). Compaction of the mask into an index list is an XLA-side
stable-sort gather — on the FPGA this is the egress assemble stage.

interpret=True for CPU-PJRT executability (see kernels/sgd.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Items per grid block: one "engine chunk" (BUFFER_SIZE x PARALLELISM on
# the FPGA = 16384 items).
BLOCK = 16384


def _select_kernel(lo_ref, hi_ref, data_ref, mask_ref, count_ref):
    lo = lo_ref[0]
    hi = hi_ref[0]
    v = data_ref[...]
    m = jnp.logical_and(v >= lo, v <= hi)
    mask_ref[...] = m.astype(jnp.int32)
    count_ref[0] = jnp.sum(m.astype(jnp.int32))


@jax.jit
def range_select_mask(data, lo, hi):
    """Blocked range selection.

    Args:
      data: (m,) int32 column, m a multiple of BLOCK (callers pad).
      lo, hi: inclusive range bounds, int32 scalars or shape-(1,) arrays.

    Returns:
      mask: (m,) int32 0/1 match mask.
      counts: (m // BLOCK,) int32 per-block match counts.
    """
    m = data.shape[0]
    assert m % BLOCK == 0, f"pad input to a multiple of {BLOCK}"
    nblocks = m // BLOCK
    lo = jnp.asarray(lo, jnp.int32).reshape((1,))
    hi = jnp.asarray(hi, jnp.int32).reshape((1,))
    return pl.pallas_call(
        _select_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # lo broadcast to all blocks
            pl.BlockSpec((1,), lambda i: (0,)),  # hi
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((nblocks,), jnp.int32),
        ],
        interpret=True,
    )(lo, hi, data)


@jax.jit
def compact_indexes(mask):
    """Egress stage: mask -> padded index list.

    Returns the indexes of set mask bits first (in order), padded with -1
    to the input length — a stable partition, which is what the FPGA's
    assemble stage streams out (modulo its per-lane padding layout).
    """
    m = mask.shape[0]
    idx = jnp.arange(m, dtype=jnp.int32)
    matched = jnp.where(mask > 0, idx, -1)
    key = jnp.where(mask > 0, 0, 1).astype(jnp.int32)
    perm = jnp.argsort(key, stable=True)
    return matched[perm]
