"""AOT compiler: lower the Layer-2 model to HLO text artifacts for the
Rust runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out-dir, default ../artifacts):

  sgd_epoch_<name>_b<B>.hlo.txt   one epoch of minibatch SGD for each
                                  Table II dataset shape x minibatch size
  sgd_epoch_tiny_{ridge,logistic}_b16.hlo.txt   small shapes for tests
  select_mask.hlo.txt             the range-selection kernel (1 block)

plus `manifest.tsv`: one artifact per line,
  name \t file \t kind \t m \t n \t minibatch \t task
which the Rust artifact registry parses (no serde in the offline crate
set, so the manifest is TSV rather than JSON).

Usage: cd python && python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import select as select_kernel


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# (name, samples, features, task, minibatches) — Table II shapes plus the
# tiny test shapes. IM gets B in {1, 4, 16} for Fig. 11; everything else
# uses the paper's default B = 16.
SGD_SHAPES = [
    ("im", 41600, 2048, model.LOGISTIC, (1, 4, 16)),
    ("mnist", 50000, 784, model.LOGISTIC, (16,)),
    ("aea", 32768, 126, model.LOGISTIC, (16,)),
    ("syn", 262144, 256, model.RIDGE, (16,)),
    ("tiny_ridge", 256, 32, model.RIDGE, (16,)),
    ("tiny_logistic", 256, 32, model.LOGISTIC, (16,)),
]

TASK_NAMES = {model.RIDGE: "ridge", model.LOGISTIC: "logistic"}


def lower_sgd_epoch(m, n, minibatch, task):
    f32 = jnp.float32
    x = jax.ShapeDtypeStruct((n,), f32)
    feats = jax.ShapeDtypeStruct((m, n), f32)
    labels = jax.ShapeDtypeStruct((m,), f32)
    scalar = jax.ShapeDtypeStruct((), f32)

    def fn(x, feats, labels, alpha, lam):
        return (
            model.sgd_epoch(
                x, feats, labels, alpha, lam, minibatch=minibatch, task=task
            ),
        )

    return jax.jit(fn).lower(x, feats, labels, scalar, scalar)


def lower_select(items):
    data = jax.ShapeDtypeStruct((items,), jnp.int32)
    bound = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(data, lo, hi):
        mask, counts = select_kernel.range_select_mask(data, lo, hi)
        return (mask, counts)

    return jax.jit(fn).lower(data, bound, bound)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--quick",
        action="store_true",
        help="only the tiny test artifacts (fast CI)",
    )
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []

    shapes = SGD_SHAPES if not args.quick else [s for s in SGD_SHAPES if "tiny" in s[0]]
    for name, m, n, task, batches in shapes:
        for b in batches:
            art = f"sgd_epoch_{name}_b{b}"
            path = os.path.join(args.out_dir, art + ".hlo.txt")
            text = to_hlo_text(lower_sgd_epoch(m, n, b, task))
            with open(path, "w") as f:
                f.write(text)
            manifest.append(
                (art, art + ".hlo.txt", "sgd_epoch", m, n, b, TASK_NAMES[task])
            )
            print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    items = select_kernel.BLOCK * 4
    art = "select_mask"
    path = os.path.join(args.out_dir, art + ".hlo.txt")
    text = to_hlo_text(lower_select(items))
    with open(path, "w") as f:
        f.write(text)
    manifest.append((art, art + ".hlo.txt", "select", items, 0, 0, "-"))
    print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        for row in manifest:
            f.write("\t".join(str(c) for c in row) + "\n")
    print(f"{len(manifest)} artifacts -> {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
