"""Layer-2 JAX model: minibatch-SGD training epoch for generalized linear
models (the paper's Algorithm 3), calling the Layer-1 Pallas kernel.

One `sgd_epoch` = a `lax.scan` over minibatches in sample order, carrying
the model vector — the scan's sequential carry IS the paper's preserved
read-after-write dependency (§VI: no stale updates). `aot.py` lowers this
function, shape-specialized per dataset and minibatch size, to HLO text
the Rust runtime executes.

Performance notes (L2 optimization pass, see EXPERIMENTS.md §Perf):
  * scan (not a Python loop / unroll) keeps the HLO compact and lets XLA
    pipeline the minibatch bodies;
  * features are reshaped once to (n_batches, B, n) outside the scan —
    no per-step dynamic slicing of the full dataset;
  * hyperparameters (alpha, lambda) are runtime scalars, so one artifact
    serves the entire hyperparameter grid.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import sgd as sgd_kernel

RIDGE = sgd_kernel.RIDGE
LOGISTIC = sgd_kernel.LOGISTIC


@functools.partial(jax.jit, static_argnames=("minibatch", "task"))
def sgd_epoch(x, features, labels, alpha, lam, *, minibatch, task):
    """One epoch of minibatch SGD.

    Args:
      x: (n,) f32 model (carry).
      features: (m, n) f32; the tail m % minibatch samples are skipped,
        exactly like the Rust engine's final short batch policy when
        shapes are pre-aligned (workload generators emit aligned m).
      labels: (m,) f32.
      alpha, lam: f32 scalars.
      minibatch: static B.
      task: RIDGE or LOGISTIC (static).

    Returns: (n,) f32 updated model.
    """
    m, n = features.shape
    nb = m // minibatch
    a_batches = features[: nb * minibatch].reshape(nb, minibatch, n)
    b_batches = labels[: nb * minibatch].reshape(nb, minibatch)

    def step(carry, ab):
        a, b = ab
        carry = sgd_kernel.sgd_minibatch(carry, a, b, alpha, lam, task=task)
        return carry, ()

    x, _ = jax.lax.scan(step, x, (a_batches, b_batches))
    return x


def make_loss(task):
    """Regularized training loss (Eq. 1) as a jitted closure."""

    @jax.jit
    def loss(x, features, labels, lam):
        z = features @ x
        if task == LOGISTIC:
            per = jnp.logaddexp(0.0, z) - labels * z
        else:
            per = 0.5 * (z - labels) ** 2
        return jnp.mean(per) + lam * jnp.dot(x, x)

    return loss
