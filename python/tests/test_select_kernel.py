"""L1 correctness: the Pallas range-selection kernel vs the numpy oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import select as k


def run(data, lo, hi):
    mask, counts = k.range_select_mask(data.astype(np.int32), lo, hi)
    return np.asarray(mask), np.asarray(counts)


def test_basic_mask_and_counts():
    data = np.arange(k.BLOCK * 2, dtype=np.int32)
    mask, counts = run(data, 10, 19)
    want_mask, want_idx = ref.range_select_ref(data, 10, 19)
    np.testing.assert_array_equal(mask, want_mask)
    assert counts.sum() == 10
    assert counts.shape == (2,)
    # All matches are in block 0.
    assert counts[0] == 10 and counts[1] == 0


@settings(max_examples=20, deadline=None)
@given(
    blocks=st.integers(1, 3),
    lo=st.integers(0, 1000),
    span=st.integers(0, 1000),
    seed=st.integers(0, 2**31 - 1),
)
def test_swept_against_ref(blocks, lo, span, seed):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 1200, blocks * k.BLOCK).astype(np.int32)
    mask, counts = run(data, lo, lo + span)
    want_mask, want_idx = ref.range_select_ref(data, lo, lo + span)
    np.testing.assert_array_equal(mask, want_mask)
    # Per-block counts partition the total.
    assert counts.sum() == want_idx.shape[0]
    for i in range(blocks):
        blk = mask[i * k.BLOCK : (i + 1) * k.BLOCK]
        assert counts[i] == blk.sum()


def test_compact_indexes_matches_nonzero():
    rng = np.random.default_rng(3)
    data = rng.integers(0, 100, k.BLOCK).astype(np.int32)
    mask, _ = run(data, 0, 49)
    padded = np.asarray(k.compact_indexes(mask))
    _, want_idx = ref.range_select_ref(data, 0, 49)
    got = padded[padded >= 0]
    np.testing.assert_array_equal(got, want_idx)
    # Padding is -1 and trails the matches.
    assert (padded[len(got):] == -1).all()


def test_empty_and_full_selectivity():
    data = np.arange(k.BLOCK, dtype=np.int32)
    mask, counts = run(data, 10, 9)  # empty range
    assert mask.sum() == 0 and counts.sum() == 0
    mask, counts = run(data, 0, k.BLOCK)  # everything
    assert mask.sum() == k.BLOCK and counts[0] == k.BLOCK
