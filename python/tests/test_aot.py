"""AOT path: lowering produces parseable HLO text with the right entry
signature, and the manifest enumerates every artifact."""

import os

import numpy as np

from compile import aot, model


def test_tiny_sgd_lowering_has_entry_and_params():
    lowered = aot.lower_sgd_epoch(64, 16, 16, model.RIDGE)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # 5 parameters: x, features, labels, alpha, lambda.
    assert "f32[16]" in text  # model vector
    assert "f32[64,16]" in text  # features
    assert "while" in text.lower() or "call" in text.lower()  # the scan


def test_select_lowering():
    text = aot.to_hlo_text(aot.lower_select(aot.select_kernel.BLOCK))
    assert "ENTRY" in text
    assert "s32[16384]" in text


def test_quick_artifact_emission(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.main(["--out-dir", out, "--quick"])
    files = sorted(os.listdir(out))
    assert "manifest.tsv" in files
    assert any(f.startswith("sgd_epoch_tiny_ridge") for f in files)
    assert "select_mask.hlo.txt" in files
    rows = [
        line.split("\t")
        for line in open(os.path.join(out, "manifest.tsv")).read().splitlines()
    ]
    assert all(len(r) == 7 for r in rows)
    names = {r[0] for r in rows}
    assert "sgd_epoch_tiny_logistic_b16" in names
    # Every listed file exists and is non-trivial HLO text.
    for r in rows:
        p = os.path.join(out, r[1])
        assert os.path.getsize(p) > 1000
        head = open(p).read(4000)
        assert "HloModule" in head


def test_lowered_epoch_still_computes_correctly():
    # Executing the jitted (pre-AOT) function must equal the oracle — the
    # same computation the Rust runtime will run from the HLO text.
    from compile.kernels import ref

    rng = np.random.default_rng(5)
    m, n = 64, 16
    feats = rng.uniform(-1, 1, (m, n)).astype(np.float32)
    labels = rng.uniform(-1, 1, m).astype(np.float32)
    x = np.zeros(n, np.float32)
    got = np.asarray(
        model.sgd_epoch(
            x, feats, labels, np.float32(0.1), np.float32(0.0),
            minibatch=16, task=model.RIDGE,
        )
    )
    want = ref.sgd_epoch_ref(x, feats, labels, 0.1, 0.0, 16, model.RIDGE)
    np.testing.assert_allclose(got, want, rtol=3e-5)
