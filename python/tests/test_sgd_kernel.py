"""L1 correctness: the Pallas SGD kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and hyperparameters; the kernel must match the
oracle to f32 tolerance for both tasks.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import sgd as k


def make_case(rng, batch, n):
    x = rng.uniform(-1, 1, n).astype(np.float32)
    a = rng.uniform(-1, 1, (batch, n)).astype(np.float32)
    b = rng.uniform(-1, 1, batch).astype(np.float32)
    return x, a, b


@pytest.mark.parametrize("task", [k.RIDGE, k.LOGISTIC])
def test_matches_ref_basic(task):
    rng = np.random.default_rng(0)
    x, a, b = make_case(rng, 16, 64)
    got = k.sgd_minibatch(x, a, b, 0.1, 1e-3, task=task)
    want = ref.sgd_minibatch_ref(x, a, b, 0.1, 1e-3, task=task)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.sampled_from([1, 2, 4, 8, 16]),
    n=st.sampled_from([1, 7, 16, 126, 256]),
    alpha=st.floats(1e-4, 0.5),
    lam=st.floats(0.0, 0.1),
    task=st.sampled_from([k.RIDGE, k.LOGISTIC]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_swept(batch, n, alpha, lam, task, seed):
    rng = np.random.default_rng(seed)
    x, a, b = make_case(rng, batch, n)
    got = np.asarray(k.sgd_minibatch(x, a, b, alpha, lam, task=task))
    want = np.asarray(ref.sgd_minibatch_ref(x, a, b, alpha, lam, task=task))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_zero_step_is_identity_up_to_reg():
    rng = np.random.default_rng(1)
    x, a, b = make_case(rng, 8, 32)
    got = np.asarray(k.sgd_minibatch(x, a, b, 0.0, 0.5, task=k.RIDGE))
    np.testing.assert_allclose(got, x, atol=1e-7)


def test_descends_ridge_loss():
    rng = np.random.default_rng(2)
    n = 32
    truth = rng.uniform(-1, 1, n).astype(np.float32)
    a = rng.uniform(-1, 1, (16, n)).astype(np.float32)
    b = (a @ truth).astype(np.float32)
    x = np.zeros(n, np.float32)
    before = float(np.mean((a @ x - b) ** 2))
    for _ in range(300):
        x = np.asarray(k.sgd_minibatch(x, a, b, 0.1, 0.0, task=k.RIDGE))
    after = float(np.mean((a @ x - b) ** 2))
    assert after < before * 0.01, (before, after)
