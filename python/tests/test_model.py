"""L2 correctness: the scan-based epoch vs the per-minibatch numpy loop."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def make_problem(rng, m, n):
    truth = rng.uniform(-1, 1, n).astype(np.float32)
    feats = rng.uniform(-1, 1, (m, n)).astype(np.float32)
    labels = (feats @ truth + 0.01 * rng.standard_normal(m)).astype(np.float32)
    return feats, labels


@pytest.mark.parametrize("task", [model.RIDGE, model.LOGISTIC])
@pytest.mark.parametrize("minibatch", [1, 4, 16])
def test_epoch_matches_ref(task, minibatch):
    rng = np.random.default_rng(7)
    feats, labels = make_problem(rng, 128, 24)
    if task == model.LOGISTIC:
        labels = (labels > 0).astype(np.float32)
    x0 = np.zeros(24, np.float32)
    got = np.asarray(
        model.sgd_epoch(
            x0, feats, labels, np.float32(0.1), np.float32(1e-3),
            minibatch=minibatch, task=task,
        )
    )
    want = ref.sgd_epoch_ref(x0, feats, labels, 0.1, 1e-3, minibatch, task)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([32, 48, 130]),  # 130: non-multiple-of-B tail
    n=st.sampled_from([8, 33]),
    seed=st.integers(0, 2**31 - 1),
)
def test_epoch_swept(m, n, seed):
    rng = np.random.default_rng(seed)
    feats, labels = make_problem(rng, m, n)
    x0 = rng.uniform(-0.1, 0.1, n).astype(np.float32)
    got = np.asarray(
        model.sgd_epoch(
            x0, feats, labels, np.float32(0.05), np.float32(0.0),
            minibatch=16, task=model.RIDGE,
        )
    )
    want = ref.sgd_epoch_ref(x0, feats, labels, 0.05, 0.0, 16, model.RIDGE)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


def test_multi_epoch_training_converges():
    rng = np.random.default_rng(11)
    feats, labels = make_problem(rng, 256, 32)
    loss = model.make_loss(model.RIDGE)
    x = np.zeros(32, np.float32)
    l0 = float(loss(x, feats, labels, np.float32(0.0)))
    for _ in range(20):
        x = model.sgd_epoch(
            x, feats, labels, np.float32(0.05), np.float32(0.0),
            minibatch=16, task=model.RIDGE,
        )
    l1 = float(loss(np.asarray(x), feats, labels, np.float32(0.0)))
    assert l1 < 0.02 * l0, (l0, l1)
