//! Quickstart: the whole stack in one file.
//!
//! 1. Microbenchmark the simulated HBM (the paper's Fig. 2 sweep);
//! 2. Submit a range selection to the 14-engine FPGA model through the
//!    `OffloadRequest` builder + async `JobHandle` API and compare
//!    against the CPU baseline;
//! 3. Train a GLM through the AOT-compiled HLO artifacts on the PJRT
//!    runtime (Python never runs here — `make artifacts` already did).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use hbm_analytics::cpu;
use hbm_analytics::db::{FpgaAccelerator, OffloadRequest};
use hbm_analytics::engines::sgd::SgdHyperParams;
use hbm_analytics::hbm::{fig2_sweep, FabricClock, HbmConfig};
use hbm_analytics::runtime::{Runtime, SgdEpochExecutor};
use hbm_analytics::workloads::datasets::{DatasetSpec, TaskKind};
use hbm_analytics::workloads::SelectionWorkload;

fn main() -> anyhow::Result<()> {
    // ---- 1. HBM microbenchmark -----------------------------------------
    println!("== HBM bandwidth vs address separation (32 ports, 200 MHz) ==");
    let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
    for (_, sep, gbs) in fig2_sweep(&cfg, &[32], &[256, 128, 64, 0]) {
        println!("  separation {sep:>3} MiB -> {gbs:>6.1} GB/s");
    }

    // ---- 2. FPGA-offloaded selection ------------------------------------
    println!("\n== range selection: FPGA engines vs CPU ==");
    let w = SelectionWorkload::uniform(4_000_000, 0.05, 42);
    let mut acc = FpgaAccelerator::new(cfg.clone());
    // submit() is async: it returns a JobHandle immediately; wait_*()
    // drives the simulated card. The .key names the column for the
    // HBM-resident cache, so a resubmission would skip its copy-in.
    let handle = acc.submit(
        OffloadRequest::select(w.lo, w.hi).on(&w.data).key("bench", "v"),
    );
    let (fpga_idx, timing) = handle.wait_selection();
    let mut cpu_idx = cpu::selection::range_select(&w.data, w.lo, w.hi, 8);
    cpu_idx.sort_unstable();
    assert_eq!(fpga_idx[..], cpu_idx[..], "FPGA and CPU must agree");
    let gbs = (w.data.len() * 4) as f64 / timing.exec / 1e9;
    println!(
        "  {} matches of {} items; simulated device rate {gbs:.1} GB/s \
         (paper: 154 GB/s at 14 engines)",
        fpga_idx.len(),
        w.data.len()
    );

    // ---- 3. HLO-compiled SGD on the PJRT runtime ------------------------
    println!("\n== SGD through AOT artifacts (PJRT CPU) ==");
    let spec = DatasetSpec {
        name: "tiny",
        samples: 256,
        features: 32,
        task: TaskKind::Regression,
        epochs: 10,
    };
    let d = spec.generate(7);
    let mut rt = match Runtime::from_default_dir() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("  artifacts not built ({e:#}); run `make artifacts`");
            return Ok(());
        }
    };
    println!("  platform: {}", rt.platform());
    let exec =
        SgdEpochExecutor::new(&mut rt, "sgd_epoch_tiny_ridge_b16", &d.features, &d.labels)?;
    let params = SgdHyperParams {
        task: exec.task,
        alpha: 0.05,
        lambda: 0.0,
        minibatch: 16,
        epochs: 10,
    };
    let (model, history) = exec.train(&mut rt, &params)?;
    let first = cpu::sgd::loss(&d.features, &d.labels, 32, &history[0], &params);
    let last = cpu::sgd::loss(&d.features, &d.labels, 32, &model, &params);
    println!("  loss epoch 1: {first:.5} -> epoch 10: {last:.5}");
    assert!(last < first, "training must descend");
    println!("\nquickstart OK");
    Ok(())
}
