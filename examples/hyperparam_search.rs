//! The paper's §VI headline use case: hyperparameter search — 28
//! independent SGD training jobs over the same dataset — run three ways:
//!
//! 1. CPU baseline (parallel std::threads, the Xeon/POWER9 analogue);
//! 2. FPGA engine fleet (14 engines × 2 rounds, replicated placement,
//!    simulated device timing);
//! 3. the winning configuration re-trained through the AOT-compiled HLO
//!    artifact on the PJRT runtime to confirm the selected model.
//!
//! Run: `make artifacts && cargo run --release --example hyperparam_search`

use hbm_analytics::cpu;
use hbm_analytics::db::{FpgaAccelerator, OffloadRequest};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::runtime::{Runtime, SgdEpochExecutor};
use hbm_analytics::workloads::datasets::{DatasetSpec, TaskKind};

fn main() -> anyhow::Result<()> {
    // A scaled IM-like problem (2048 features, binary labels) so the
    // functional search finishes in seconds; rates in `hbmctl figures
    // --fig 10a` use the same machinery at larger scale.
    let spec = DatasetSpec {
        name: "im-mini",
        samples: 1024,
        features: 256,
        task: TaskKind::Binary,
        epochs: 5,
    };
    println!("dataset: {} ({} x {})", spec.name, spec.samples, spec.features);
    let d = spec.generate(13);
    let grid = cpu::sgd::hyperparameter_grid(spec.task.glm(), 16, spec.epochs);
    println!("grid: {} configurations", grid.len());

    // ---- 1. CPU search.
    let t0 = std::time::Instant::now();
    let cpu_results = cpu::sgd::search(&d.features, &d.labels, spec.features, &grid, 8);
    let best_cpu = cpu_results
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "CPU search:  best config #{} (alpha={}, lambda={}) loss {:.5} \
         [{:?} host]",
        best_cpu.0,
        grid[best_cpu.0].alpha,
        grid[best_cpu.0].lambda,
        best_cpu.1,
        t0.elapsed()
    );

    // ---- 2. FPGA fleet (replicated placement), submitted as one grid
    //         request; the dataset key would make a follow-up grid over
    //         the same data copy-free.
    let mut acc = FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
    let (models, timing) = acc
        .submit(
            OffloadRequest::sgd(&d.features, &d.labels, spec.features, &grid)
                .key("ml", "im-mini"),
        )
        .wait_sgd();
    let mut best_fpga = (0usize, f64::INFINITY);
    for (i, model) in models.iter().enumerate() {
        let loss = cpu::sgd::loss(&d.features, &d.labels, spec.features, model, &grid[i]);
        if loss < best_fpga.1 {
            best_fpga = (i, loss);
        }
    }
    println!(
        "FPGA fleet:  best config #{} loss {:.5} \
         [simulated: copy-in {:.1} ms + exec {:.1} ms + copy-out {:.2} ms]",
        best_fpga.0,
        best_fpga.1,
        timing.copy_in * 1e3,
        timing.exec * 1e3,
        timing.copy_out * 1e3,
    );
    assert_eq!(best_cpu.0, best_fpga.0, "both paths must pick the same winner");
    let copy_fraction = timing.copy_in / timing.total();
    println!(
        "copy-in is {:.1}% of total (paper: <1% at 10 epochs x 28 jobs on IM)",
        copy_fraction * 100.0
    );

    // ---- 3. Confirm the winner through the PJRT runtime (tiny artifact
    //         shape; the full Table-II artifacts work identically).
    match Runtime::from_default_dir() {
        Ok(mut rt) => {
            let tiny = DatasetSpec {
                name: "tiny",
                samples: 256,
                features: 32,
                task: TaskKind::Binary,
                epochs: 5,
            }
            .generate(14);
            let exec = SgdEpochExecutor::new(
                &mut rt,
                "sgd_epoch_tiny_logistic_b16",
                &tiny.features,
                &tiny.labels,
            )?;
            let mut params = grid[best_fpga.0].clone();
            params.epochs = 5;
            let (model, _) = exec.train(&mut rt, &params)?;
            let loss = cpu::sgd::loss(&tiny.features, &tiny.labels, 32, &model, &params);
            println!("runtime confirmation (HLO path, tiny shape): loss {loss:.5}");
        }
        Err(e) => eprintln!("runtime skipped (build artifacts first): {e:#}"),
    }
    println!("hyperparam_search OK");
    Ok(())
}
