//! DB analytics scenario: the paper's §III integration story end-to-end.
//!
//! A MonetDB-style catalog holds an orders/customers schema; we run a
//! selection + join + aggregation query three ways — on the CPU operator
//! path, as the historical operator-at-a-time offload walk, and as a
//! whole-query pipeline (`submit_plan`) whose dependent stages consume
//! their parents' outputs directly from HBM — verify identical results,
//! and report the host bytes each offload path moved, the data-movement
//! tradeoff §III is about. Finally, two whole queries are submitted
//! concurrently and collected out of order.
//!
//! Run: `cargo run --release --example db_analytics`

use hbm_analytics::db::{Executor, FpgaAccelerator, PipelineRequest};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::workloads::analytics;

fn main() {
    let orders = 2_000_000;
    let customers = 2_000;
    println!("catalog: {orders} orders, {customers} customers");
    let cat = analytics::orders_catalog(orders, customers, 99);

    // Query: count order rows of the low half of the customer-id range
    // (key-range pruning), via join against the customers table.
    //   SELECT count(*) FROM customers c JOIN orders o ON c.ckey = o.cust
    //   WHERE o.cust <= :half
    let count = analytics::key_range_join_count(customers);

    // --- CPU path.
    let t0 = std::time::Instant::now();
    let cpu_count = Executor::cpu(&cat, 8).run(&count).expect("cpu plan");
    println!("CPU path:            {cpu_count:?}  ({:?} host)", t0.elapsed());

    // --- Operator-at-a-time offload: one blocking submission per
    //     select/join, the projected probe side round-tripping through
    //     the host (what the paper's integration pays).
    let mut acc_op = FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
    let t1 = std::time::Instant::now();
    let op_count = Executor::accelerated(&cat, 8, &mut acc_op)
        .operator_at_a_time()
        .run(&count)
        .expect("operator-at-a-time plan");
    let op_bytes = acc_op.stats().total_copy_in_bytes();
    println!(
        "operator-at-a-time:  {op_count:?}  ({:?} host, {op_bytes} B over the link)",
        t1.elapsed()
    );

    // --- Whole-plan pipeline: the executor lowers the plan into a
    //     dependency-linked stage DAG; the join consumes the selection's
    //     output as an HBM-resident (pinned) intermediate.
    let mut acc_pipe =
        FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
    let t2 = std::time::Instant::now();
    let pipe_count = Executor::accelerated(&cat, 8, &mut acc_pipe)
        .run(&count)
        .expect("pipelined plan");
    let pipe_bytes = acc_pipe.stats().total_copy_in_bytes();
    println!(
        "pipelined plan:      {pipe_count:?}  ({:?} host, {pipe_bytes} B over the link)",
        t2.elapsed()
    );
    assert_eq!(cpu_count, op_count, "offloaded plan must be result-identical");
    assert_eq!(cpu_count, pipe_count, "pipelined plan must be result-identical");
    assert!(
        pipe_bytes < op_bytes,
        "the pipeline must skip the probe-side host round-trip"
    );
    println!(
        "pipelining saved {} B of OpenCAPI traffic ({:.1}%)",
        op_bytes - pipe_bytes,
        100.0 * (op_bytes - pipe_bytes) as f64 / op_bytes as f64
    );

    // --- Two whole queries in flight on one card, collected out of
    //     order — what the blocking per-operator API could never express.
    let sum_big = analytics::amount_band_sum(9_000, 9_999);
    let h_count = acc_pipe.submit_plan(
        PipelineRequest::from_plan(&count, &cat).expect("lowerable").client(0),
    );
    let h_sum = acc_pipe.submit_plan(
        PipelineRequest::from_plan(&sum_big, &cat).expect("lowerable").client(1),
    );
    println!(
        "submitted 2 whole-query pipelines concurrently ({} stage jobs in flight)",
        acc_pipe.in_flight()
    );
    let (sum_result, sum_report) = h_sum.take_scalar();
    let (count_repeat, count_report) = h_count.take();
    println!(
        "collected out of order: sum {sum_result:?} ({} B copied), repeat \
         count {count_repeat:?} ({} B copied — fully HBM-resident repeat)",
        sum_report.copy_in_bytes(),
        count_report.copy_in_bytes(),
    );
    assert_eq!(count_repeat, cpu_count);
    assert_eq!(
        count_report.copy_in_bytes(),
        0,
        "repeat of a keyed plan on a warm card must copy nothing"
    );
    println!("db_analytics OK");
}
