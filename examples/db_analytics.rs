//! DB analytics scenario: the paper's §III integration story end-to-end.
//!
//! A MonetDB-style catalog holds an orders/customers schema; we run a
//! selection + join + aggregation query twice — once on the CPU operator
//! path, once with the select and join offloaded to the simulated
//! HBM-FPGA through the UDF hook — verify identical results, and report
//! the accelerator's simulated timing breakdown (copy-in / exec /
//! copy-out), the data-movement tradeoff §III is about.
//!
//! Run: `cargo run --release --example db_analytics`

use hbm_analytics::db::ops::AggKind;
use hbm_analytics::db::{
    Catalog, Column, Executor, FpgaAccelerator, OffloadRequest, Plan, Table,
};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::util::rng::Xoshiro256;

fn build_catalog(orders: usize, customers: usize) -> Catalog {
    let mut rng = Xoshiro256::new(99);
    let mut cat = Catalog::new();
    cat.register(Table::new(
        "orders",
        vec![
            Column::u32("okey", (0..orders as u32).collect()),
            Column::u32(
                "cust",
                (0..orders).map(|_| rng.next_u32() % customers as u32).collect(),
            ),
            Column::u32(
                "amount",
                (0..orders).map(|_| rng.next_u32() % 10_000).collect(),
            ),
        ],
    ));
    cat.register(Table::new(
        "customers",
        vec![Column::u32("ckey", (0..customers as u32).collect())],
    ));
    cat
}

fn main() {
    let orders = 2_000_000;
    let customers = 2_000;
    println!("catalog: {orders} orders, {customers} customers");
    let cat = build_catalog(orders, customers);

    // Query: for big-ticket orders (amount in [9000, 9999]), join to the
    // customers table and count matched order rows.
    //   SELECT count(*) FROM customers c JOIN orders o ON c.ckey = o.cust
    //   WHERE o.amount BETWEEN 9000 AND 9999
    let candidates = Plan::scan("orders", "amount").select(9000, 9999);
    let probe_keys = Plan::scan("orders", "cust").project(candidates);
    let join = Plan::scan("customers", "ckey").join(probe_keys);
    let count = Plan::scan("customers", "ckey")
        .project(join.clone().join_side(true))
        .aggregate(AggKind::Count);

    // --- CPU path.
    let t0 = std::time::Instant::now();
    let cpu_count = Executor::cpu(&cat, 8).run(&count);
    println!("CPU path:  {cpu_count:?}  ({:?} host)", t0.elapsed());

    // --- FPGA-offloaded path (selection + join engines).
    let mut acc = FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
    let t1 = std::time::Instant::now();
    let fpga_count = Executor::accelerated(&cat, 8, &mut acc).run(&count);
    println!("FPGA path: {fpga_count:?}  ({:?} host)", t1.elapsed());
    assert_eq!(
        format!("{cpu_count:?}"),
        format!("{fpga_count:?}"),
        "offloaded plan must be result-identical"
    );

    // --- Simulated-device timing breakdown for the join in isolation:
    //     first query vs subsequent queries. The request names both sides
    //     with (table, column) keys, so the first submission pays the
    //     OpenCAPI copy-in and the repeat runs against HBM-resident
    //     columns — the paper's distinction, expressed per request.
    let s: Vec<u32> = (0..customers as u32).collect();
    let l = cat.table("orders").unwrap().column("cust").unwrap();
    let l = l.data.as_u32().unwrap();
    let mut acc = FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
    let request = || {
        OffloadRequest::join(&s, l)
            .key("customers", "ckey")
            .probe_key("orders", "cust")
    };
    for label in ["first query (cold copy-in)", "repeat query (HBM-resident)"] {
        let (_, t) = acc.submit(request()).wait_join();
        println!(
            "join offload, {label}: copy-in {:.3} ms, exec {:.3} ms, \
             copy-out {:.3} ms -> rate {:.2} GB/s",
            t.copy_in * 1e3,
            t.exec * 1e3,
            t.copy_out * 1e3,
            (l.len() * 4) as f64 / t.total() / 1e9,
        );
    }

    // --- Async submission: keep two operators in flight on one card and
    //     collect them in either order — what the blocking offload_* API
    //     could never express.
    let amount = cat.table("orders").unwrap().column("amount").unwrap();
    let sel = acc.submit(
        OffloadRequest::select(9000, 9999)
            .on(amount.data.as_u32().unwrap())
            .key("orders", "amount"),
    );
    let join2 = acc.submit(request());
    println!(
        "submitted selection + join concurrently ({} jobs in flight)",
        acc.in_flight()
    );
    let (pairs, _) = join2.wait_join();
    let (cands, _) = sel.wait_selection();
    println!(
        "collected out of order: {} join pairs, {} selection candidates",
        pairs.len(),
        cands.len()
    );
    println!("db_analytics OK");
}
