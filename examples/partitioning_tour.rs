//! A tour of the paper's central lesson: *data placement decides whether
//! HBM pays off*. Walks the same SGD fleet through four placements and
//! shows the bandwidth cliff, plus the floorplan/timing consequences of
//! scaling the fleet up.
//!
//! Run: `cargo run --release --example partitioning_tour`

use hbm_analytics::engines::sgd::{GlmTask, SgdEngine, SgdHyperParams, SgdJob};
use hbm_analytics::engines::{sim, Engine};
use hbm_analytics::floorplan::{floorplan, BitstreamSpec, EngineKind};
use hbm_analytics::hbm::{FabricClock, HbmConfig, HbmMemory, Shim};
use hbm_analytics::workloads::datasets::{DatasetSpec, TaskKind};

fn fleet_rate(cfg: &HbmConfig, replicate: bool, engines: usize) -> f64 {
    let spec = DatasetSpec {
        name: "syn-mini",
        samples: 512,
        features: 256,
        task: TaskKind::Regression,
        epochs: 2,
    };
    let d = spec.generate(5);
    let flat = d.flat();
    let bytes = (flat.len() * 4) as u64;
    let mut mem = HbmMemory::new();
    let mut shim = Shim::new(cfg.clone());
    let shared = if replicate {
        None
    } else {
        let b = shim.alloc(0, bytes).unwrap();
        b.write_f32s(&mut mem, 0, &flat);
        Some(b)
    };
    let mut fleet: Vec<Box<dyn Engine>> = Vec::new();
    for e in 0..engines {
        let data = match shared {
            Some(b) => b,
            None => {
                let b = shim.alloc(e, bytes).unwrap();
                b.write_f32s(&mut mem, 0, &flat);
                b
            }
        };
        let model_out = shim.alloc(e, (spec.features * 4 + 64) as u64).unwrap();
        fleet.push(Box::new(SgdEngine::new(
            cfg.clone(),
            SgdJob {
                data,
                n_samples: spec.samples,
                n_features: spec.features,
                params: SgdHyperParams {
                    task: GlmTask::Ridge,
                    alpha: 0.05,
                    lambda: 0.0,
                    minibatch: 16,
                    epochs: 2,
                },
                model_out,
            },
        )));
    }
    let report = sim::run(cfg, &mut mem, &mut fleet);
    (engines as u64 * bytes * 2) as f64 / report.makespan
}

fn main() {
    let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
    println!("== placement decides bandwidth (14 SGD engines) ==");
    for (label, replicate, engines) in [
        ("1 engine, private channel", true, 1),
        ("14 engines, replicated per channel", true, 14),
        ("14 engines, single shared copy", false, 14),
    ] {
        let rate = fleet_rate(&cfg, replicate, engines);
        println!("  {label:<38} {:>7.1} GB/s", rate / 1e9);
    }
    println!("  (paper Fig. 10a: 156 GB/s replicated vs ~12.8 flat shared)");

    println!("\n== what the fabric allows (floorplan / timing) ==");
    for engines in [2usize, 7, 14, 20, 28] {
        let spec = BitstreamSpec { kind: EngineKind::Sgd, engines };
        let rep = spec.report();
        let fp = floorplan(&spec);
        println!(
            "  {engines:>2} SGD engines: LUT {:>5.1}%  URAM {:>5.1}%  -> {} MHz{}{}",
            rep.util.lut * 100.0,
            rep.util.uram * 100.0,
            fp.achieved_clock.mhz(),
            if fp.assignments.iter().any(|a| a.crossings > 0) {
                ", crosses SLRs"
            } else {
                ""
            },
            if rep.fits && fp.feasible { "" } else { "  [DOES NOT FIT]" },
        );
    }
    println!("partitioning_tour OK");
}
